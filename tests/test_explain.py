"""Query EXPLAIN and shadow-verification tests (repro.obs.explain /
repro.obs.shadow): witness correctness against the BiBFS oracle and the
dict-layout index, backend agreement, cache/coalescing dispositions,
sharded routing hops, and the shadow verifier's divergence detection on
a deliberately corrupted index."""
import numpy as np
import pytest

from repro.core.baselines import bibfs_rlc
from repro.core.queries import biased_true_queries, sample_index_queries
from repro.graphgen import erdos_renyi, random_delta
from repro.obs.explain import (NEGATIVE_REASONS, WITNESS_SCHEMA,
                               explain_rows, replay_witness,
                               verify_witness_entries)
from repro.obs.shadow import ShadowVerifier
from repro.service import RLCService, ServiceConfig
from repro.service.sharded import ShardedRLCService, ShardedServiceConfig

K = 2


@pytest.fixture(scope="module")
def served():
    g = erdos_renyi(150, 3.5, 3, seed=11)
    svc = RLCService.build(g, ServiceConfig(k=K, batch_size=16))
    qs = biased_true_queries(g, K, n=40, seed=7)
    yield g, svc, qs
    svc.close()


# ------------------------------------------------------------------ #
# Witness correctness: the acceptance-criterion property
# ------------------------------------------------------------------ #
def test_positive_witnesses_replay_true_under_oracle(served):
    g, svc, qs = served
    for s, t, L in qs.true_queries:
        b = svc.explain(s, t, L)
        assert b["answer"] is True
        w = b["witness"]
        assert w["schema"] == WITNESS_SCHEMA
        assert w["kind"] in ("case2_out", "case2_in", "case1")
        assert replay_witness(g, b) is True
        assert verify_witness_entries(svc.index, w, b["mr"])


def test_negative_witnesses_replay_false_and_name_a_reason(served):
    g, svc, qs = served
    for s, t, L in qs.false_queries:
        b = svc.explain(s, t, L)
        assert b["answer"] is False
        w = b["witness"]
        assert w["kind"] == "negative"
        assert w["negative"]["reason"] in NEGATIVE_REASONS
        assert replay_witness(g, b) is False
        assert verify_witness_entries(svc.index, w, b["mr"])


def test_explain_agrees_with_query_across_backends(served):
    g, svc, qs = served
    queries = (qs.true_queries + qs.false_queries)[:30]
    for backend in ("sorted", "numpy", "python"):
        b_svc = RLCService.build(
            g, ServiceConfig(k=K, backend=backend,
                             use_device=(backend == "sorted")),
            index=svc.index)
        for s, t, L in queries:
            bundle = b_svc.explain(s, t, L)
            assert bundle["answer"] == b_svc.query(s, t, L), (backend, s, t)
        b_svc.close()


def test_case1_hubs_exist_on_both_sides(served):
    g, svc, qs = served
    seen_case1 = False
    for s, t, L in qs.true_queries:
        w = svc.explain(s, t, L)["witness"]
        if w["kind"] != "case1":
            continue
        seen_case1 = True
        assert w["join_hubs"] >= 1
        assert len(w["hubs"]) == min(w["join_hubs"], 8)
        for h in w["hubs"]:
            assert svc.index.has_out(s, h["hub"], tuple(L))
            assert svc.index.has_in(t, h["hub"], tuple(L))
    assert seen_case1    # the workload must actually exercise the join


# ------------------------------------------------------------------ #
# explain_rows unit behavior
# ------------------------------------------------------------------ #
def test_explain_rows_pad_filtering_matches_exact_rows():
    oh = np.array([3, 7, -1, -1], np.int32)
    om = np.array([0, 1, -1, -1], np.int32)
    ih = np.array([7, -1], np.int32)
    im = np.array([1, -1], np.int32)
    padded = explain_rows(oh, om, ih, im, 0, 9, 1, pad=-1)
    exact = explain_rows(oh[:2], om[:2], ih[:1], im[:1], 0, 9, 1)
    assert padded == exact
    assert padded["answer"] is True
    assert padded["kind"] == "case1"
    assert [h["hub"] for h in padded["hubs"]] == [7]


def test_explain_rows_negative_reasons():
    e = np.empty(0, np.int32)
    r = explain_rows(e, e, e, e, 0, 1, 0)
    assert r["negative"]["reason"] == "empty_out_row"
    one = np.array([5], np.int32)
    mr0 = np.array([0], np.int32)
    r = explain_rows(one, mr0, e, e, 0, 1, 0)
    assert r["negative"]["reason"] == "empty_in_row"
    # both rows non-empty, queried mr only on the in side
    r = explain_rows(one, np.array([1], np.int32), one, mr0, 0, 1, 0)
    assert r["negative"]["reason"] == "no_out_candidates"
    r = explain_rows(one, mr0, one, np.array([1], np.int32), 0, 1, 0)
    assert r["negative"]["reason"] == "no_in_candidates"
    r = explain_rows(np.array([5], np.int32), mr0,
                     np.array([6], np.int32), mr0, 0, 1, 0)
    assert r["negative"]["reason"] == "disjoint_hub_sets"


def test_witness_hub_cap_and_truncation_flag():
    hubs = np.arange(20, dtype=np.int32)
    mrs = np.zeros(20, np.int32)
    w = explain_rows(hubs, mrs, hubs, mrs, 100, 101, 0)
    assert w["join_hubs"] == 20
    assert len(w["hubs"]) == 8
    assert w["truncated"] is True


# ------------------------------------------------------------------ #
# Service dispositions: cache / coalescing, and non-mutation
# ------------------------------------------------------------------ #
def test_explain_reports_cache_disposition_without_mutating(served):
    g, svc, qs = served
    s, t, L = qs.true_queries[0]
    key = (s, t, svc.mr_ids[tuple(L)])
    svc.cache.clear()
    b = svc.explain(s, t, L)
    assert b["cache"] == dict(disposition="miss", answer=None)
    assert svc.cache.peek(key) is None       # explain didn't populate it
    svc.query(s, t, L)                        # now it's cached
    lookups_before = svc.cache.stats.lookups
    b = svc.explain(s, t, L)
    assert b["cache"] == dict(disposition="hit", answer=True)
    # the probe is invisible to the serving hit-rate series
    assert svc.cache.stats.lookups == lookups_before


def test_explain_reports_coalescing_disposition(served):
    g, svc, qs = served
    s, t, L = qs.true_queries[1]
    mr_id = svc.mr_ids[tuple(L)]
    svc.cache.clear()
    assert svc.explain(s, t, L)["coalesced"] is False
    svc.batcher.submit(s, t, mr_id, len(L))   # leave it queued, unflushed
    assert svc.explain(s, t, L)["coalesced"] is True
    svc.batcher.drain()


def test_explain_span_lands_in_chrome_trace():
    g = erdos_renyi(60, 3.0, 3, seed=2)
    svc = RLCService.build(g, ServiceConfig(k=K, trace_sample_rate=1.0))
    svc.explain(0, 1, (0,))
    names = [e.get("name") for e in
             svc.chrome_trace()["traceEvents"]]
    assert "explain" in names
    assert svc.obs.registry.get("rlc_explain_requests") is not None
    svc.close()


# ------------------------------------------------------------------ #
# Sharded EXPLAIN: routing hops
# ------------------------------------------------------------------ #
def test_sharded_explain_routes_and_matches_single_host(served):
    g, svc, qs = served
    sh = ShardedRLCService.build(
        g, ShardedServiceConfig(k=K, num_shards=3), index=svc.index)
    paths = set()
    for s, t, L in (qs.true_queries + qs.false_queries)[:40]:
        b = sh.explain(s, t, L)
        assert b["answer"] == svc.query(s, t, L)
        route = b["route"]
        assert route["shard_s"] == sh.plan.shard_of(s)
        assert route["shard_t"] == sh.plan.shard_of(t)
        assert route["home"] == route["shard_t"]
        paths.add(route["path"])
        if route["path"] == "remote":
            assert b["backend"] == "digest"
            assert route["digest_entries"] >= 0
            assert route["digest_bytes"] >= 0
            assert replay_witness(g, b) == b["answer"]
    assert paths == {"local", "remote"}   # both join paths exercised
    # EXPLAIN must not skew the router's serving counters
    rst = sh.router.stats()
    assert rst["local"] == 0 and rst["remote"] == 0
    sh.close()


# ------------------------------------------------------------------ #
# Shadow verification
# ------------------------------------------------------------------ #
def test_shadow_healthy_service_zero_divergence(served):
    g, _svc, qs = served
    svc = RLCService.build(
        g, ServiceConfig(k=K, shadow_sample_rate=1.0), index=_svc.index)
    svc.query_batch(qs.true_queries + qs.false_queries)
    checked = svc.drain_shadow()
    st = svc._shadow.stats()
    assert checked == len(qs.true_queries) + len(qs.false_queries)
    assert st["divergent"] == 0
    assert st["divergences"] == 0
    snap = svc.telemetry_snapshot()
    assert snap["extra"]["shadow"]["divergent"] == 0
    svc.close()


def test_shadow_detects_corrupted_index():
    g = erdos_renyi(120, 3.5, 3, seed=13)
    svc = RLCService.build(
        g, ServiceConfig(k=K, backend="numpy", use_device=False,
                         cache_capacity=0, shadow_sample_rate=1.0))
    s, t, L = sample_index_queries(svc.frozen, svc._id_to_mr,
                                   n=1, seed=3)[0]
    assert svc.query(s, t, L) == True   # noqa: E712 — typed Answer
    svc.drain_shadow()
    assert svc._shadow.divergent == 0
    # corrupt both entry rows the query joins: the served answer flips
    # to False while the oracle still proves the path exists
    o0, o1 = svc.frozen.out_indptr[s], svc.frozen.out_indptr[s + 1]
    i0, i1 = svc.frozen.in_indptr[t], svc.frozen.in_indptr[t + 1]
    svc.frozen.out_hub[o0:o1] = -2
    svc.frozen.in_hub[i0:i1] = -2
    assert svc.query(s, t, L) == False  # noqa: E712 — corrupted serving path
    assert bibfs_rlc(g, s, t, L) is True          # ground truth unchanged
    svc.drain_shadow()
    st = svc._shadow.stats()
    assert st["divergent"] >= 1
    assert len(svc._shadow.divergences) >= 1
    bundle = svc._shadow.divergences[0]
    assert bundle["served_answer"] is False
    assert bundle["oracle"] is True
    assert bundle["s"] == s and bundle["t"] == t
    svc.close()


def test_shadow_discards_pending_across_delta():
    g = erdos_renyi(80, 3.0, 3, seed=5)
    svc = RLCService.build(
        g, ServiceConfig(k=K, use_device=False,
                         shadow_sample_rate=1.0))
    qs = biased_true_queries(g, K, n=10, seed=2)
    svc.query_batch(qs.true_queries)
    assert svc._shadow.stats()["pending"] > 0
    svc.apply_delta(random_delta(svc.graph, 4, 2,
                                 np.random.default_rng(9)))
    assert svc._shadow.stats()["pending"] == 0
    assert svc._shadow.discarded > 0
    # post-delta answers verify cleanly against the mutated graph
    qs2 = biased_true_queries(svc.graph, K, n=10, seed=3)
    svc.query_batch(qs2.true_queries)
    svc.drain_shadow()
    assert svc._shadow.divergent == 0
    svc.close()


def test_shadow_queue_bound_drops_oldest():
    g = erdos_renyi(30, 2.0, 2, seed=1)
    svc = RLCService.build(g, ServiceConfig(k=K, use_device=False))
    sv = ShadowVerifier(svc, sample_rate=1.0, max_pending=4)
    for i in range(10):
        sv.offer(0, i % 30, 0, False)
    st = sv.stats()
    assert st["pending"] == 4
    assert st["dropped"] == 6
    assert st["offered"] == 10
    svc.close()


def test_shadow_sampling_rate_zero_disables():
    g = erdos_renyi(40, 2.5, 2, seed=6)
    svc = RLCService.build(g, ServiceConfig(k=K, use_device=False))
    assert svc._shadow is None                  # default rate is 0
    assert svc.drain_shadow() == 0
    assert svc.stats()["shadow"] is None
    svc.close()


def test_shadow_background_thread_drains():
    g = erdos_renyi(60, 3.0, 3, seed=8)
    svc = RLCService.build(
        g, ServiceConfig(k=K, use_device=False, shadow_sample_rate=1.0,
                         shadow_background=True))
    assert svc._shadow.running
    qs = biased_true_queries(g, K, n=8, seed=2)
    svc.query_batch(qs.true_queries)
    deadline = 100
    while svc._shadow.stats()["pending"] and deadline:
        import time
        time.sleep(0.02)
        deadline -= 1
    assert svc._shadow.stats()["pending"] == 0
    assert svc._shadow.divergent == 0
    svc.close()
    assert not svc._shadow.running
