import os
import sys

# Make `import repro` work regardless of PYTHONPATH (tests are documented to
# run as `PYTHONPATH=src pytest tests/`, this is belt-and-braces).
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Smoke tests and benches must see exactly ONE device; only launch/dryrun.py
# sets the 512-device flag (in its own process, before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# The container has no `hypothesis` and nothing may be pip-installed; fall
# back to the deterministic stub so the property-test modules still run.
_TESTS = os.path.dirname(os.path.abspath(__file__))
if _TESTS not in sys.path:
    sys.path.insert(0, _TESTS)
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
