import os
import sys

# Make `import repro` work regardless of PYTHONPATH (tests are documented to
# run as `PYTHONPATH=src pytest tests/`, this is belt-and-braces).
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Smoke tests and benches must see exactly ONE device; only launch/dryrun.py
# sets the 512-device flag (in its own process, before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
