"""Serving engine: greedy generation matches step-by-step full forwards."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import forward, init_model
from repro.serve import ServeEngine


@pytest.mark.parametrize("name", ["qwen3-0.6b-smoke", "mamba2-2.7b-smoke",
                                  "zamba2-1.2b-smoke"])
def test_generate_matches_forward_rollout(name):
    cfg = get_config(name)
    params, _ = init_model(cfg, jax.random.PRNGKey(3))
    B, S0, steps = 2, 8, 6
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (B, S0)).astype(np.int32)

    engine = ServeEngine(cfg, params, max_len=S0 + steps + 2,
                         batch_slots=B)
    got = engine.generate(prompts, steps=steps)

    # reference: greedy rollout via repeated full forward
    toks = jnp.asarray(prompts)
    ref = []
    for _ in range(steps):
        logits, _ = forward(params, cfg, toks)
        nxt = jnp.argmax(logits[:, -1:, :cfg.vocab_size], -1
                         ).astype(jnp.int32)
        ref.append(np.asarray(nxt))
        toks = jnp.concatenate([toks, nxt], axis=1)
    ref = np.concatenate(ref, axis=1)
    np.testing.assert_array_equal(got, ref)
