"""Multi-process RPC shard serving (ISSUE-10 tentpole).

Three layers, cheapest first: wire-codec unit tests, :class:`ShardWorker`
protocol tests driven without any process, then real spawn-based cluster
tests (worker count env-gated via ``RLC_RPC_WORKERS``, default 2) —
bit-identical answers vs the single-process service across mid-stream
hot-swap/apply_delta, worker death, and leave/rejoin. The heavy
shards x replicas sweep is ``slow``-marked.
"""
import os

import numpy as np
import pytest

from repro.build import build_rlc_index
from repro.core.minimum_repeat import enumerate_mrs, mr_id_space
from repro.graphgen import erdos_renyi, random_delta
from repro.service import RLCService, ServiceConfig
from repro.service.rpc import ShardWorker, wire
from repro.service.rpc.controller import _slice_payload
from repro.service.sharded import ShardedRLCService, ShardedServiceConfig
from repro.service.stats import validate_stats

K = 2
#: CI knob: how many worker processes the cheap cluster tests may spawn
WORKERS = max(1, int(os.environ.get("RLC_RPC_WORKERS", "2")))


def _graph(n=80, seed=11):
    return erdos_renyi(n, 3.0, 3, seed=seed)


def _queries(g, n=40, seed=0):
    rng = np.random.default_rng(seed)
    st = rng.integers(0, g.num_vertices, size=(n, 2))
    mrs = list(enumerate_mrs(g.num_labels, K))
    return [(int(s), int(t), mrs[i % len(mrs)])
            for i, (s, t) in enumerate(st)]


def _single(g, **kw):
    cfg = dict(k=K, batch_size=8, backend="numpy", use_device=False)
    cfg.update(kw)
    return RLCService.build(g, ServiceConfig(**cfg))


def _rpc(g, num_shards=None, num_replicas=1, **kw):
    cfg = dict(k=K, batch_size=8, backend="numpy", use_device=False,
               num_shards=num_shards or min(WORKERS, 2),
               num_replicas=num_replicas, transport="rpc")
    cfg.update(kw)
    return ShardedRLCService.build(g, ShardedServiceConfig(**cfg))


def _bools(answers):
    return [bool(a) for a in answers]


# ------------------------------------------------------------------ #
# Wire codec
# ------------------------------------------------------------------ #
def test_wire_roundtrips_scalars_arrays_and_nesting():
    doc = dict(
        method="execute", id=7, ok=True, name="s0r1",
        s=np.arange(5, dtype=np.int32),
        aid=np.array([2 ** 40, -3], dtype=np.int64),
        flags=np.array([True, False]),
        nested=dict(hub=np.empty(0, dtype=np.int32), note=None),
        seq=[1, "two", 3.5])
    out = wire.decode(wire.encode(doc))
    assert out["method"] == "execute" and out["id"] == 7
    assert out["ok"] is True and out["nested"]["note"] is None
    for path, want in ((("s",), doc["s"]), (("aid",), doc["aid"]),
                       (("flags",), doc["flags"]),
                       (("nested", "hub"), doc["nested"]["hub"])):
        got = out
        for k in path:
            got = got[k]
        assert isinstance(got, np.ndarray)
        assert got.dtype == want.dtype and got.shape == want.shape
        np.testing.assert_array_equal(got, want)
    assert list(out["seq"]) == [1, "two", 3.5]


def test_wire_codec_name_is_declared():
    assert wire.codec_name() in ("msgpack", "json")


# ------------------------------------------------------------------ #
# ShardWorker protocol (no processes)
# ------------------------------------------------------------------ #
def _worker_for(g, lo, hi, generation=0):
    idx = build_rlc_index(g, K)
    ids = mr_id_space(g.num_labels, K)
    frozen = idx.freeze(ids)
    id_to_mr = [mr for mr, _ in sorted(ids.items(), key=lambda kv: kv[1])]
    payload = _slice_payload(frozen.slice_rows(lo, hi), lo, hi,
                             generation, id_to_mr)
    w = ShardWorker("t0")
    reply = w.on_init(dict(payload, shard_id=0, replica_id=0))
    assert reply["generation"] == generation
    return w, frozen, idx, ids


def test_shard_worker_executes_its_slice():
    g = _graph(50, seed=3)
    w, frozen, idx, ids = _worker_for(g, 0, g.num_vertices)
    qs = _queries(g, 16, seed=1)
    s = np.array([q[0] for q in qs], dtype=np.int32)
    t = np.array([q[1] for q in qs], dtype=np.int32)
    mr = np.array([ids[q[2]] for q in qs], dtype=np.int32)
    reply, keep = w.handle(dict(method="execute", id=1,
                                s=s, t=t, mr=mr, n_real=len(s)))
    assert keep and reply["ok"]
    want = [idx.query(int(a), int(b), q[2])
            for a, b, q in zip(s, t, qs)]
    assert list(reply["ans"]) == want
    stats, _ = w.handle(dict(method="stats", id=2))
    assert stats["queries"] == len(qs) and stats["batches"] == 1


def test_shard_worker_rejects_unknown_method_and_stale_swap():
    g = _graph(40, seed=5)
    w, frozen, _idx, ids = _worker_for(g, 0, g.num_vertices, generation=3)
    reply, keep = w.handle(dict(method="frobnicate", id=9))
    assert keep and not reply["ok"] and "unknown method" in reply["error"]
    id_to_mr = [mr for mr, _ in sorted(ids.items(), key=lambda kv: kv[1])]
    stale = _slice_payload(frozen.slice_rows(0, g.num_vertices), 0,
                           g.num_vertices, 1, id_to_mr)
    reply, keep = w.handle(dict(stale, method="swap", id=10))
    assert keep and not reply["ok"] and "stale swap" in reply["error"]
    assert w.generation == 3 and w.swaps == 0
    fresh = _slice_payload(frozen.slice_rows(0, g.num_vertices), 0,
                           g.num_vertices, 4, id_to_mr)
    reply, keep = w.handle(dict(fresh, method="swap", id=11))
    assert reply["ok"] and w.generation == 4 and w.swaps == 1


def test_shard_worker_digest_hop_matches_direct_execution():
    g = _graph(60, seed=7)
    mid = g.num_vertices // 2
    w_lo, frozen, idx, ids = _worker_for(g, 0, mid)
    w_hi = ShardWorker("t1")
    id_to_mr = [mr for mr, _ in sorted(ids.items(), key=lambda kv: kv[1])]
    w_hi.on_init(dict(_slice_payload(frozen.slice_rows(mid,
                                                       g.num_vertices),
                                     mid, g.num_vertices, 0, id_to_mr),
                      shard_id=1, replica_id=0))
    # cross-shard queries: s on the low shard, t on the high shard
    qs = [(s, t, mr) for s, t, mr in _queries(g, 24, seed=2)
          if s < mid <= t]
    assert qs, "need at least one genuinely cross-shard query"
    s = np.array([q[0] for q in qs], dtype=np.int64)
    dig, _ = w_lo.handle(dict(method="gather_digest", id=1, s=s))
    assert dig["ok"]
    join, _ = w_hi.handle(dict(
        method="join_digest", id=2, s=s,
        t=np.array([q[1] for q in qs], dtype=np.int64),
        mr=np.array([ids[q[2]] for q in qs], dtype=np.int64),
        digest_indptr=dig["indptr"], digest_hub=dig["hub"],
        digest_mr=dig["mr"]))
    assert join["ok"]
    want = [idx.query(int(a), int(b), mr) for a, b, mr in qs]
    assert list(join["ans"]) == want
    assert w_lo.digests == len(qs) and w_hi.joins == len(qs)


# ------------------------------------------------------------------ #
# Spawn-based cluster (env-gated: RLC_RPC_WORKERS)
# ------------------------------------------------------------------ #
def test_rpc_cluster_matches_single_process():
    g = _graph()
    qs = _queries(g)
    single = _single(g)
    want = _bools(single.query_batch(qs))
    single.close()
    svc = _rpc(g)
    try:
        got = svc.query_batch(qs)
        assert _bools(got) == want
        backends = {a.backend for a in got}
        assert backends <= {"rpc:numpy", "rpc:sorted", "rpc:python",
                            "rpc:digest"}
        if svc.config.num_shards > 1:
            assert "rpc:digest" in backends, "no cross-shard query ran"
        st = validate_stats(svc.stats())
        assert st["transport"] == "rpc"
        assert st["rpc"]["live_workers"] == \
            svc.config.num_shards * svc.config.num_replicas
        assert st["rpc"]["wire_bytes"]["sent"] > 0
        assert st["rpc"]["wire_bytes"]["received"] > 0
        # cached re-ask never goes back over the wire
        again = svc.query_batch(qs)
        assert {a.disposition for a in again} == {"cache_hit"}
    finally:
        svc.close()
    assert all(not h.proc.is_alive()
               for hs in svc.cluster.handles.values() for h in hs)


def test_rpc_async_submit_with_mid_stream_swap():
    g = _graph(seed=13)
    qs = _queries(g, 32, seed=4)
    single = _single(g)
    want = _bools(single.query_batch(qs))
    single.close()
    svc = _rpc(g)
    try:
        with svc.start():
            futs = [svc.submit(s, t, c) for s, t, c in qs[:16]]
            swapped = svc.hot_swap()
            futs += [svc.submit(s, t, c) for s, t, c in qs[16:]]
            svc._engine.flush()
            got = [f.result(timeout=60) for f in futs]
        assert _bools(got) == want
        assert swapped >= 1
        assert svc.stats()["rpc"]["generation"] == svc.generation
    finally:
        svc.close()


def test_rpc_apply_delta_matches_reference():
    g = _graph(60, seed=17)
    svc = _rpc(g, delta_fallback_frac=1.0)
    rng = np.random.default_rng(23)
    try:
        for _ in range(2):
            delta = random_delta(svc.graph, 2, 2, rng)
            svc.apply_delta(delta)
            qs = _queries(svc.graph, 24, seed=int(rng.integers(1 << 30)))
            got = svc.query_batch(qs)
            ref = build_rlc_index(svc.graph, K, backend="python")
            want = [ref.query(s, t, mr) for s, t, mr in qs]
            assert _bools(got) == want
        assert svc.deltas_applied == 2
    finally:
        svc.close()


def test_rpc_worker_death_fails_over_to_sibling_replica():
    g = _graph(seed=19)
    qs = _queries(g, 30, seed=6)
    single = _single(g)
    want = _bools(single.query_batch(qs))
    single.close()
    svc = _rpc(g, num_shards=1, num_replicas=2)
    try:
        victim = svc.cluster.handles[0][0]
        victim.proc.terminate()
        victim.proc.join(timeout=10)
        got = svc.query_batch(qs)
        assert _bools(got) == want
        st = svc.stats()["rpc"]
        assert st["live_workers"] == 1
        assert st["retries"] >= 1
        assert not victim.alive
    finally:
        svc.close()


def test_rpc_worker_leave_and_rejoin_mid_stream():
    g = _graph(seed=29)
    qs = _queries(g, 30, seed=8)
    single = _single(g)
    want = _bools(single.query_batch(qs))
    single.close()
    svc = _rpc(g, num_shards=min(WORKERS, 2), num_replicas=1)
    try:
        base = svc.query_batch(qs)
        assert _bools(base) == want
        svc.cluster.leave(0, 0)
        svc.cache.clear()
        degraded = svc.query_batch(qs)
        assert _bools(degraded) == want, \
            "answers must stay exact while shard 0 has no workers"
        assert any(a.disposition == "degraded" for a in degraded), \
            "losing every replica of a shard must surface as degraded"
        svc.cluster.rejoin(0, 0)
        svc.cache.clear()
        healed = svc.query_batch(qs)
        assert _bools(healed) == want
        assert all(a.disposition != "degraded" for a in healed)
        st = validate_stats(svc.stats())["rpc"]
        assert st["leaves"] == 1 and st["rejoins"] == 1
        assert st["membership_epoch"] >= 2
    finally:
        svc.close()


@pytest.mark.slow
def test_rpc_bit_identical_sweep_shards_by_replicas():
    """The acceptance sweep: shards {1,2,4} x replicas {1,2}, each cell
    bit-identical to the single-process service, including a mid-stream
    hot swap."""
    g = _graph(100, seed=31)
    qs = _queries(g, 60, seed=9)
    single = _single(g)
    want = _bools(single.query_batch(qs))
    single.close()
    for num_shards in (1, 2, 4):
        for num_replicas in (1, 2):
            svc = _rpc(g, num_shards=num_shards,
                       num_replicas=num_replicas)
            try:
                assert _bools(svc.query_batch(qs)) == want, \
                    f"shards={num_shards} replicas={num_replicas}"
                svc.hot_swap()
                svc.cache.clear()
                assert _bools(svc.query_batch(qs)) == want, \
                    f"post-swap shards={num_shards} " \
                    f"replicas={num_replicas}"
                validate_stats(svc.stats())
            finally:
                svc.close()
