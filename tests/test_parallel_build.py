"""Parallel build backend: bit-identicality of the epoch/merge protocol.

The ``parallel`` backend's contract is *exact* equivalence to the
sequential reference — entries AND pruning counters — for every worker
count, executor, DAG shaping, and pruning-flag ablation (the ablations
exercise all three validation paths: dirty-set version tracking with
PR2 on, content fingerprints with PR2 off, and the read-free path with
PR1 off). A forced-conflict configuration (no DAG edge analysis at
all) drives the stale-re-run repair machinery on purpose and must
still be exact. Scheduler/DAG/mirror units and the service + telemetry
integration ride along; the heavy cross-product lives under
``@pytest.mark.slow``.
"""
import os

import numpy as np
import pytest

from repro.build import build_rlc_index_with_stats, get_backend
from repro.build.parallel import (HubSliceMirror, ListScheduler,
                                  ParallelBackend, PhaseCostModel,
                                  PhaseDAG)
from repro.build.base import access_schedule
from repro.graphgen import (erdos_renyi, fig2_graph,
                            random_labeled_graph)

#: CI pins this to 2 so tier-1 exercises the protocol at fixed width
WORKERS = int(os.environ.get("RLC_PARALLEL_WORKERS", "2"))


def entry_sets(idx):
    out = tuple(sorted((v, h, m) for v, d in enumerate(idx.l_out)
                       for h, ms in d.items() for m in ms))
    inn = tuple(sorted((v, h, m) for v, d in enumerate(idx.l_in)
                       for h, ms in d.items() for m in ms))
    return out, inn


def assert_bit_identical(g, k, flags=None, **kw):
    flags = flags or {}
    ref_idx, ref_st = build_rlc_index_with_stats(g, k, backend="python",
                                                 **flags)
    kw.setdefault("workers", WORKERS)
    kw.setdefault("executor", "inline")
    be = ParallelBackend(**flags, **kw)
    idx, st = be.build(g, k)
    assert entry_sets(idx) == entry_sets(ref_idx), (flags, kw)
    assert st.counters() == ref_st.counters(), (flags, kw)
    return be


# ------------------------------------------------------------------ #
# Property sweep: V, |L|, k, loop density x workers x pruning flags
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("k,num_labels,loops", [
    (1, 2, 0.0), (2, 2, 0.2), (2, 3, 0.0), (3, 2, 0.3)])
def test_parallel_matches_python_random(seed, k, num_labels, loops):
    g = random_labeled_graph(num_vertices=14, num_edges=46,
                             num_labels=num_labels, seed=seed,
                             self_loop_frac=loops)
    assert_bit_identical(g, k)


@pytest.mark.parametrize("workers", [1, 2, 3, 4])
def test_parallel_worker_counts(workers):
    g = erdos_renyi(28, 2.5, 3, seed=5)
    be = assert_bit_identical(g, 2, workers=workers)
    info = be.last_build_info
    assert info["mode"] == ("sequential" if workers == 1 else "parallel")


@pytest.mark.parametrize("flags", [
    dict(use_pr2=False),                  # content-fingerprint path
    dict(use_pr1=False),                  # read-free phases
    dict(use_pr3=False),
    dict(use_pr1=False, use_pr2=False, use_pr3=False)])
def test_parallel_pruning_ablations(flags):
    g = random_labeled_graph(num_vertices=16, num_edges=52,
                             num_labels=2, seed=11, self_loop_frac=0.2)
    assert_bit_identical(g, 2, flags=flags)


def test_parallel_fig2_exact():
    g, _ = fig2_graph()
    be = assert_bit_identical(g, 2)
    assert be.last_build_info["mode"] in ("parallel", "sequential")


# ------------------------------------------------------------------ #
# Forced conflicts: no edge analysis -> speculation must mis-predict
# ------------------------------------------------------------------ #
def test_forced_conflicts_repair_exactly():
    """With the DAG stripped to intra-hub edges only (hot_prefix=0,
    locality=0) the scheduler speculates across real dependencies; the
    stale-re-run path must fire and the result must still be exact."""
    g = erdos_renyi(40, 2.5, 2, seed=3)
    be = assert_bit_identical(g, 2, workers=4, hot_prefix=0, locality=0,
                              auto_thin=False)
    info = be.last_build_info
    assert info["mode"] == "parallel"
    assert info["stale_reruns"] > 0
    assert info["epochs"] > 0


def test_process_executor_matches():
    g = erdos_renyi(24, 2.0, 3, seed=7)
    be = assert_bit_identical(g, 2, workers=2, executor="process")
    assert be.last_build_info["executor"] == "process"


def test_registered_backend_and_env_default(monkeypatch):
    monkeypatch.setenv("RLC_PARALLEL_WORKERS", "3")
    be = get_backend("parallel")
    assert isinstance(be, ParallelBackend) and be.workers == 3


# ------------------------------------------------------------------ #
# Units: DAG, scheduler, sliced mirror, accounting
# ------------------------------------------------------------------ #
def test_phase_dag_edges_point_forward():
    g = erdos_renyi(30, 2.0, 3, seed=1)
    order, _ = access_schedule(g)
    dag = PhaseDAG(g, 2, order)
    for p, preds in enumerate(dag.preds):
        assert all(q < p for q in preds)
    st = dag.stats(np.ones(dag.npos))
    assert st["phases"] > 0 and st["depth"] >= 1
    assert 0.0 < st["serial_fraction"] <= 1.0
    assert st["max_width"] >= st["mean_width"] > 0


def test_scheduler_plans_disjoint_and_windowed():
    g = erdos_renyi(40, 2.5, 3, seed=2)
    order, _ = access_schedule(g)
    dag = PhaseDAG(g, 2, order)
    cm = PhaseCostModel(np.ones(dag.npos))
    sched = ListScheduler(dag, cm, workers=3)
    committed = ~dag.active.copy()
    inflight = set()
    plans = []
    for _ in range(3):
        plan = sched.plan_for(committed, [], inflight, 0)
        assert plan == sorted(plan)
        assert not inflight.intersection(plan)
        assert all(p < ListScheduler.WINDOW for p in plan)
        inflight.update(plan)
        plans.append(plan)
    assert plans[0]     # frontier position is always dispatchable
    flat = [p for plan in plans for p in plan]
    assert len(flat) == len(set(flat))   # plans never overlap


def test_hub_slice_mirror_bytes_track():
    m = HubSliceMirror(num_mrs=3, num_vertices=64)
    assert m.size_bytes() == 0
    m.set1(m.out, 1, 5, 33)
    m.set1(m.in_, 2, 6, 12)
    n1 = m.size_bytes()
    assert n1 > 0 and m.peak_bytes == n1
    m.out.apply_mask(5, 1, 1 << 33)
    assert m.out.row_int(5, 1) == 1 << 33
    # running byte tally must equal a from-scratch walk
    expect = (len(m.out.blocks) * m.out.C * m.out.W
              + sum((v.bit_length() + 7) // 8 + 16
                    for d in m.out.rows.values() for v in d.values()))
    assert m.out.bytes_now() == expect
    m.out.clear_row(5)
    assert m.out.row_int(5, 1) == 0


def test_peak_mirror_bytes_recorded():
    g = erdos_renyi(30, 2.5, 3, seed=9)
    be = ParallelBackend(workers=2, executor="inline")
    _, st = be.build(g, 2)
    assert st.peak_mirror_bytes > 0
    info = be.last_build_info
    assert info["makespan_s"] > 0 or info["mode"] == "sequential"
    if info["mode"] == "parallel":
        assert len(info["worker_busy_s"]) == 2
        assert info["epochs"] >= 1


# ------------------------------------------------------------------ #
# Service + telemetry integration
# ------------------------------------------------------------------ #
def test_service_builds_with_parallel_backend():
    from repro.service import RLCService, ServiceConfig
    g = erdos_renyi(24, 2.0, 3, seed=4)
    svc = RLCService.build(g, ServiceConfig(k=2,
                                            build_backend="parallel"))
    ref, _ = build_rlc_index_with_stats(g, 2, backend="python")
    assert entry_sets(svc.index) == entry_sets(ref)
    # delta rebuilds degrade to a batched sequential backend
    assert svc._delta_backend_name() == "numpy"


def test_parallel_build_obs_series():
    from repro.obs import MetricsRegistry
    from repro.obs.build_obs import BuildPhaseObserver
    g = erdos_renyi(30, 2.5, 3, seed=6)
    reg = MetricsRegistry()
    obs = BuildPhaseObserver(reg, context="full")
    be = ParallelBackend(workers=2, executor="inline")
    be.set_observer(obs)
    be.build(g, 2)
    snap = reg.as_dict()
    if be.last_build_info["mode"] == "parallel":
        epochs = sum(s["value"]
                     for s in snap["rlc_build_epochs"]["series"])
        assert epochs == be.last_build_info["epochs"]
        assert snap["rlc_build_epoch_seconds"]["series"]
        workers = {s["labels"]["worker"] for s in
                   snap["rlc_build_worker_phase_seconds"]["series"]}
        assert workers   # at least one worker committed phases
    # per-phase series exist either way
    assert snap["rlc_build_phase_seconds"]["series"]


# ------------------------------------------------------------------ #
# Heavy sweep (nightly)
# ------------------------------------------------------------------ #
@pytest.mark.slow
@pytest.mark.parametrize("workers", [2, 4])
@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("k,num_labels,loops", [
    (2, 2, 0.2), (2, 4, 0.0), (3, 2, 0.0), (3, 3, 0.25), (4, 2, 0.1)])
def test_parallel_sweep_slow(workers, seed, k, num_labels, loops):
    g = random_labeled_graph(num_vertices=30, num_edges=110,
                             num_labels=num_labels, seed=seed,
                             self_loop_frac=loops)
    for flags in (dict(), dict(use_pr2=False),
                  dict(use_pr1=False, use_pr2=False, use_pr3=False)):
        assert_bit_identical(g, k, flags=flags, workers=workers)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(4))
def test_forced_conflict_sweep_slow(seed):
    g = erdos_renyi(50, 3.0, 3, seed=seed)
    assert_bit_identical(g, 2, workers=4, hot_prefix=0, locality=0,
                         auto_thin=False)
