"""Incremental (delta) build benchmark: apply-a-delta vs full rebuild.

For each paper stand-in, a live-update workload mutates the graph with
small insert/delete batches and measures :class:`repro.build.delta.
DeltaBuilder.apply` against the cost of the full batched (numpy) rebuild
the serving system would otherwise pay per update:

* ``single`` — a stream of single-edge-pair deltas (one insert + one
  delete each), the high-frequency maintenance shape;
* ``batch``  — one ~1%-of-edges delta, the coarse refresh shape.

Each apply is verified bit-identical (entries + counters) against a
from-scratch numpy rebuild of the mutated graph. The artifact
(``benchmarks/artifacts/delta.json``) records per-graph speedups, the
replay/re-run/fallback accounting, and the headline
``best_single_speedup`` — the acceptance bar is >= 3x on a <=1%-edge
delta, which the sparse stand-ins clear; dense few-label stand-ins
(AD) legitimately hit the fallback path (single-label kernels percolate,
so even one edge touches most hubs' traversals) and are reported as
such rather than hidden.

One end-to-end serving row times :meth:`RLCService.apply_delta` (delta
build + partial re-freeze + targeted cache invalidation) on the same
workload.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.build import DeltaBuilder, get_backend
from repro.graphgen import random_delta as _random_delta
from repro.service import RLCService, ServiceConfig

from .common import Report, standin_graph, timeit

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def entry_sets(idx):
    out = tuple(sorted((v, h, m) for v, d in enumerate(idx.l_out)
                       for h, ms in d.items() for m in ms))
    inn = tuple(sorted((v, h, m) for v, d in enumerate(idx.l_in)
                       for h, ms in d.items() for m in ms))
    return out, inn


def random_delta(g, n_changes: int, rng: np.random.Generator):
    n_del = n_changes // 2
    return _random_delta(g, n_changes - n_del, n_del, rng)


def _verify(db: DeltaBuilder, k: int) -> None:
    idx, stats = get_backend("numpy").build(db.graph, k)
    assert entry_sets(idx) == entry_sets(db.index), "delta diverged"
    assert stats.counters() == db.stats.counters(), "counters diverged"


def _measure_stream(db: DeltaBuilder, n: int, rng, k: int):
    """Apply ``n`` single-edge-pair deltas generated against the evolving
    graph; verify the final state."""
    times, reruns, fallbacks = [], [], 0
    for _ in range(n):
        delta = random_delta(db.graph, 2, rng)
        t0 = time.perf_counter()
        res = db.apply(delta)
        times.append(time.perf_counter() - t0)
        reruns.append(res.phases_rerun)
        fallbacks += int(res.fallback)
    _verify(db, k)
    return (float(np.mean(times)), float(np.median(times)), reruns,
            fallbacks)


def run(quick: bool = True, smoke: bool = False, k: int = 2) -> Report:
    rep = Report("delta")
    if smoke:
        graphs = [("TW", 0.5)]
        n_single, repeats = 2, 1
    else:
        names = ["AD", "EP", "TW"] if quick else ["AD", "EP", "TW", "WN",
                                                  "WG"]
        graphs = [(n, 1.0) for n in names]
        n_single, repeats = 6, 2
    rows = []
    best_single = (0.0, None)
    for name, scale in graphs:
        g = standin_graph(name, scale=scale)
        rng = np.random.default_rng(7)
        t_full = timeit(lambda: get_backend("numpy").build(g, k),
                        repeats=repeats)
        db = DeltaBuilder(g, k, fallback_frac=0.5)
        t0 = time.perf_counter()
        db.full()
        t_traced = time.perf_counter() - t0

        # single-edge-pair stream (the high-frequency update shape);
        # speedup over the median apply (means are fragile to one-off
        # allocator/GC pauses at these millisecond scales)
        t_mean, t_med, reruns, fbs = _measure_stream(db, n_single, rng, k)
        single_speedup = t_full / t_med if t_med else 0.0
        if single_speedup > best_single[0]:
            best_single = (single_speedup, name)

        # one ~1% batch delta
        nch = max(2, db.graph.num_edges // 100)
        t_batch0 = time.perf_counter()
        res_b = db.apply(random_delta(db.graph, nch, rng))
        t_batch = time.perf_counter() - t_batch0
        _verify(db, k)

        row = dict(graph=name, scale=scale, V=g.num_vertices,
                   E=g.num_edges, L=g.num_labels,
                   full_ms=round(t_full * 1e3, 1),
                   traced_full_ms=round(t_traced * 1e3, 1),
                   single_mean_ms=round(t_mean * 1e3, 1),
                   single_median_ms=round(t_med * 1e3, 1),
                   single_speedup=round(single_speedup, 2),
                   single_reruns=reruns,
                   single_fallbacks=fbs,
                   batch_edges=nch,
                   batch_ms=round(t_batch * 1e3, 1),
                   batch_speedup=round(t_full / t_batch, 2),
                   batch_fallback=res_b.fallback)
        rep.add(**row)
        rows.append(row)

    # end-to-end serving apply (build + partial re-freeze + targeted
    # cache invalidation) on the sparse stand-in
    name, scale = graphs[-1] if smoke else ("TW", 1.0)
    g = standin_graph(name, scale=scale)
    svc = RLCService.build(g, ServiceConfig(
        k=k, use_device=False, build_backend="numpy",
        delta_fallback_frac=0.5))
    rng = np.random.default_rng(11)
    svc.apply_delta(random_delta(svc.graph, 2, rng))      # bootstrap
    t0 = time.perf_counter()
    summary = svc.apply_delta(random_delta(svc.graph, 2, rng))
    t_serve = time.perf_counter() - t0
    serve_row = dict(graph=f"{name}(serve)", scale=scale,
                     serve_apply_ms=round(t_serve * 1e3, 1),
                     cache_evicted=summary["cache_evicted"],
                     dirty_rows=summary["delta"]["dirty_rows"])
    rep.add(**serve_row)

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "delta.json"), "w") as f:
        json.dump(dict(k=k, smoke=smoke,
                       best_single_speedup=round(best_single[0], 2),
                       best_single_graph=best_single[1],
                       serve=serve_row, rows=rows), f, indent=2)
    rep.add(graph="HEADLINE", best_single_speedup=round(best_single[0], 2),
            best_single_graph=best_single[1])
    return rep
