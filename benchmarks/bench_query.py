"""Paper Fig. 3: execution time of 1000 true- and 1000 false-queries:
RLC index (host merge join, device batched join, Pallas join) vs online
BFS / BiBFS traversals vs ETC lookups.
"""
from __future__ import annotations


import numpy as np

from repro.core.baselines import ETC, bfs_rlc, bibfs_rlc
from repro.core.device_index import DeviceIndex
from repro.core.index_builder import build_rlc_index
from repro.core.queries import generate_queries

from .common import Report, standin_graph, timeit


def run(quick: bool = True, smoke: bool = False, k: int = 2) -> Report:
    rep = Report("query.fig3")
    names = ["AD", "EP"] if quick else ["AD", "EP", "TW", "WN", "WG"]
    n_q = 200 if quick else 1000
    scale = 1.0
    if smoke:
        names, n_q, scale = ["AD"], 40, 0.3
    for name in names:
        g = standin_graph(name, scale=scale)
        qs = generate_queries(g, k, n_true=n_q, n_false=n_q, seed=1)
        idx = build_rlc_index(g, k)
        dev = DeviceIndex.from_index(idx, g.num_labels)
        etc = ETC(g, k)
        for label, queries in (("true", qs.true_queries),
                               ("false", qs.false_queries)):
            if not queries:
                continue
            t_idx = timeit(lambda: [idx.query(s, t, L)
                                    for s, t, L in queries])
            t_bfs = timeit(lambda: [bfs_rlc(g, s, t, L)
                                    for s, t, L in queries])
            t_bi = timeit(lambda: [bibfs_rlc(g, s, t, L)
                                   for s, t, L in queries])
            t_etc = timeit(lambda: [etc.query(s, t, L)
                                    for s, t, L in queries])
            sa = np.array([s for s, _, _ in queries], np.int32)
            ta = np.array([t for _, t, _ in queries], np.int32)
            ma = np.array([dev.mr_ids[L] for _, _, L in queries], np.int32)
            dev.query_batch(sa, ta, ma)  # warm/compile
            t_dev = timeit(lambda: dev.query_batch(sa, ta, ma))
            # correctness cross-check while we are here
            got = dev.query_batch(sa, ta, ma)
            want = label == "true"
            assert all(bool(x) == want for x in got.tolist())
            rep.add(graph=name, qset=label, n=len(queries),
                    rlc_ms=round(t_idx * 1e3, 2),
                    rlc_batch_ms=round(t_dev * 1e3, 2),
                    bfs_ms=round(t_bfs * 1e3, 2),
                    bibfs_ms=round(t_bi * 1e3, 2),
                    etc_ms=round(t_etc * 1e3, 2),
                    speedup_vs_bfs=round(t_bfs / max(t_idx, 1e-9), 1),
                    speedup_vs_bibfs=round(t_bi / max(t_idx, 1e-9), 1))
    return rep
