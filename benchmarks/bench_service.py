"""Online-service benchmark: latency / throughput / cache behavior of
:class:`repro.service.RLCService` under a Zipf request workload.

A pool of distinct queries (true + false, multi-length MRs) is sampled from
the graph; the live request stream draws from that pool with a Zipfian
popularity distribution (exponent ~1, the classic web-serving shape), so
the LRU result cache sees realistic skew. Reported per backend: batch p50 /
p99 latency, per-query p50 / p99 (arrival-to-answer within the synchronous
stream), throughput, and the end-of-run cache hit-rate.

Writes both the orchestrator CSV and a JSON report
(``benchmarks/artifacts/service.json``).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.queries import biased_true_queries
from repro.graphgen import erdos_renyi
from repro.service import RLCService, ServiceConfig

from .common import (Report, hist_summary_us, run_query_stream,
                     warm_service, zipf_weights)

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def run(quick: bool = True, smoke: bool = False, k: int = 2) -> Report:
    rep = Report("service")
    n = 300 if quick else 2000
    n_pool = 200 if quick else 1000
    n_requests = 2000 if quick else 20000
    if smoke:
        n, n_pool, n_requests = 120, 60, 300
    g = erdos_renyi(n, 3.5, 4, seed=31)

    t0 = time.perf_counter()
    base = RLCService.build(g, ServiceConfig(k=k))
    build_s = time.perf_counter() - t0
    rep.add(stage="build", V=n, E=g.num_edges, k=k,
            entries=base.index.num_entries(),
            seconds=round(build_s, 3))

    # query pool: walk-seeded true queries + oracle-verified false queries
    qs = biased_true_queries(g, k, n=n_pool // 2, seed=5)
    pool = [(s, t, L) for s, t, L in qs.true_queries + qs.false_queries]
    rng = np.random.default_rng(17)
    rng.shuffle(pool)
    weights = zipf_weights(len(pool))
    stream = [pool[i] for i in
              rng.choice(len(pool), size=n_requests, p=weights)]

    # shadow verification rides every serve run: a sample of answered
    # queries is re-executed against the BiBFS oracle at snapshot time
    # (off the timed stream); run.py fails the smoke gate on divergence
    shadow_rate = 0.1 if smoke else 0.02
    results = {}
    for backend in ("sorted", "numpy", "python"):
        svc = RLCService.build(
            g, ServiceConfig(k=k, batch_size=32, max_wait_ms=2.0,
                             cache_capacity=1024, backend=backend,
                             shadow_sample_rate=shadow_rate),
            index=base.index)
        warm = warm_service(svc, stream[:500], chunk=64, backend=backend)
        lat = run_query_stream(svc, stream, chunk=64)
        st = svc.stats()
        # label the row with the backend that actually answered (fallback
        # would otherwise silently misattribute the numbers)
        ex = st["executor"]["backends"]
        served = max(ex, key=lambda b: ex[b]["batches"])
        b = ex[served]
        # queue-wait vs compute, from the registry reservoirs: where a
        # request's latency actually went (batcher hold vs executor run)
        queue = hist_summary_us(svc.obs.registry,
                                "rlc_batcher_queue_wait_seconds")
        comp = hist_summary_us(svc.obs.registry,
                               "rlc_executor_batch_seconds")
        row = dict(
            stage="serve", backend=served, requested_backend=backend,
            requests=len(stream),
            pool=len(pool),
            q_p50_us=round(float(np.percentile(lat, 50)) * 1e6, 1),
            q_p99_us=round(float(np.percentile(lat, 99)) * 1e6, 1),
            batch_p50_ms=round(b.get("p50_ms", 0.0), 3),
            batch_p99_ms=round(b.get("p99_ms", 0.0), 3),
            queue_p50_us=queue["p50_us"], queue_p99_us=queue["p99_us"],
            exec_p50_us=comp["p50_us"], exec_p99_us=comp["p99_us"],
            qps=round(len(stream) / lat.sum(), 1),
            cache_hit_rate=round(st["cache"]["hit_rate"], 4),
            batches_full=st["scheduler"]["batches_full"],
            batches_deadline=st["scheduler"]["batches_deadline"],
            batches_drain=st["scheduler"]["batches_drain"],
            warmup_s=warm["warm_s"], compile_s=warm["compile_s"],
        )
        rep.add(**row)
        svc.audit_report(sample=64)    # embedded via snapshot extra
        results[backend] = dict(row, stats=st,
                                telemetry=svc.telemetry_snapshot())

    # cache ablation on the fastest CPU backend
    for cap in (0, 256, 4096):
        svc = RLCService.build(
            g, ServiceConfig(k=k, batch_size=32, cache_capacity=cap,
                             backend="sorted"), index=base.index)
        warm_service(svc, stream[:500], chunk=64, backend="sorted")
        lat = run_query_stream(svc, stream, chunk=64)
        st = svc.stats()
        rep.add(stage="cache_ablation", cache_capacity=cap,
                cache_hit_rate=round(st["cache"]["hit_rate"], 4),
                q_p50_us=round(float(np.percentile(lat, 50)) * 1e6, 1),
                qps=round(len(stream) / lat.sum(), 1))
        results[f"cache_{cap}"] = dict(
            cache_capacity=cap, hit_rate=st["cache"]["hit_rate"],
            qps=len(stream) / float(lat.sum()))

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "service.json"), "w") as f:
        json.dump(dict(graph=g.summary(), k=k, requests=n_requests,
                       zipf_exponent=1.0, results=results), f, indent=2,
                  default=str)
    return rep
