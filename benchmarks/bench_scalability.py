"""Paper Fig. 6: scalability in |V| at d=5, |L|=16 on ER and BA graphs."""
from __future__ import annotations

import time

from repro.core.index_builder import build_rlc_index
from repro.core.queries import generate_queries
from repro.graphgen import barabasi_albert, erdos_renyi

from .common import Report, timeit


def run(quick: bool = True, smoke: bool = False, k: int = 2) -> Report:
    rep = Report("scalability.fig6")
    sizes = (125, 250, 500) if quick else (125, 250, 500, 1000, 2000)
    n_q = 100 if quick else 1000
    if smoke:
        sizes, n_q = (125,), 40
    for fam, gen in (("ER", lambda v: erdos_renyi(v, 5, 16, seed=11)),
                     ("BA", lambda v: barabasi_albert(v, 2, 16, seed=11))):
        for v in sizes:
            g = gen(v)
            t0 = time.perf_counter()
            idx = build_rlc_index(g, k)
            it = time.perf_counter() - t0
            qs = generate_queries(g, k, n_true=n_q, n_false=n_q, seed=5)
            t_true = timeit(lambda: [idx.query(s, t, L)
                                     for s, t, L in qs.true_queries]) \
                if qs.true_queries else 0.0
            t_false = timeit(lambda: [idx.query(s, t, L)
                                      for s, t, L in qs.false_queries]) \
                if qs.false_queries else 0.0
            rep.add(family=fam, V=v, E=g.num_edges, it_s=round(it, 3),
                    is_bytes=idx.size_bytes(),
                    true_ms=round(t_true * 1e3, 2),
                    false_ms=round(t_false * 1e3, 2))
    return rep
