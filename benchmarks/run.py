"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Default is quick mode (scaled-down graphs, single-core container);
``--full`` runs paper-scale sweeps. CSVs land in benchmarks/artifacts/.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args(argv)
    quick = not args.full
    os.makedirs(ART, exist_ok=True)

    from . import (bench_device, bench_graph_chars, bench_indexing,
                   bench_k, bench_query, bench_scalability, bench_service,
                   bench_sharded, bench_systems)

    suites = {
        "indexing": lambda: bench_indexing.run(quick),
        "build_backends": lambda: bench_indexing.run_backends(quick),
        "pruning": lambda: bench_indexing.run_pruning_ablation(),
        "query": lambda: bench_query.run(quick),
        "k": lambda: bench_k.run(quick),
        "graph_chars": lambda: bench_graph_chars.run(quick),
        "scalability": lambda: bench_scalability.run(quick),
        "systems": lambda: bench_systems.run(quick),
        "device": lambda: bench_device.run(quick),
        "service": lambda: bench_service.run(quick),
        "sharded": lambda: bench_sharded.run(quick),
    }
    failures = []
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            rep = fn()
            csv = rep.to_csv()
            with open(os.path.join(ART, f"{rep.name}.csv"), "w") as f:
                f.write(csv)
            print(f"===== {name} done in {time.time()-t0:.1f}s "
                  f"({len(rep.rows)} rows) =====", flush=True)
        except Exception as e:  # pragma: no cover
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print("\nFAILED suites:", failures)
        sys.exit(1)
    print("\nAll benchmark suites completed.")


if __name__ == "__main__":
    main()
