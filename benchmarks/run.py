"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--smoke] [--only NAME]

(also runnable as ``python benchmarks/run.py``: the shim below puts the
repo root and ``src/`` on ``sys.path`` — what the CI smoke job invokes.)

Default is quick mode (scaled-down graphs, single-core container);
``--full`` runs paper-scale sweeps; ``--smoke`` runs every registered
suite at tiny sizes — it exists to fail on crash and keep per-PR JSON
artifacts flowing, not to produce meaningful numbers. CSVs (and the
JSON artifacts some suites emit) land in benchmarks/artifacts/.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):                     # script execution
    _HERE = os.path.dirname(os.path.abspath(__file__))
    _ROOT = os.path.dirname(_HERE)
    for p in (_ROOT, os.path.join(_ROOT, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)
    __package__ = "benchmarks"

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, every suite; fails on crash")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args(argv)
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    quick = not args.full
    smoke = args.smoke
    os.makedirs(ART, exist_ok=True)

    from . import (bench_delta, bench_device, bench_graph_chars,
                   bench_indexing, bench_k, bench_query, bench_scalability,
                   bench_service, bench_sharded, bench_systems)

    suites = {
        "indexing": lambda: bench_indexing.run(quick, smoke),
        "build_backends": lambda: bench_indexing.run_backends(quick, smoke),
        "pruning": lambda: bench_indexing.run_pruning_ablation(smoke),
        "delta": lambda: bench_delta.run(quick, smoke),
        "query": lambda: bench_query.run(quick, smoke),
        "k": lambda: bench_k.run(quick, smoke),
        "graph_chars": lambda: bench_graph_chars.run(quick, smoke),
        "scalability": lambda: bench_scalability.run(quick, smoke),
        "systems": lambda: bench_systems.run(quick, smoke),
        "device": lambda: bench_device.run(quick, smoke),
        "service": lambda: bench_service.run(quick, smoke),
        "sharded": lambda: bench_sharded.run(quick, smoke),
    }
    failures = []
    ran = []
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        ran.append(name)
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            rep = fn()
            csv = rep.to_csv()
            with open(os.path.join(ART, f"{rep.name}.csv"), "w") as f:
                f.write(csv)
            print(f"===== {name} done in {time.time()-t0:.1f}s "
                  f"({len(rep.rows)} rows) =====", flush=True)
        except Exception as e:  # pragma: no cover
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    failures.extend(validate_telemetry_artifacts(ran))
    if smoke and not args.only:
        from .regression import gate
        failures.extend(gate(ART))
    if failures:
        print("\nFAILED suites:", failures)
        sys.exit(1)
    print("\nAll benchmark suites completed.")


def validate_telemetry_artifacts(ran):
    """Check the telemetry the serving suites just emitted: every snapshot
    embedded in their JSON artifacts must parse against the versioned
    schema, the Chrome trace dump must be well-formed, every embedded
    index-health audit report must validate (and is consolidated into
    ``artifacts/audit.json``), and the shadow verifier must report zero
    divergences. Runs only for the suites that actually executed; returns
    ``(name, error)`` failure tuples in the orchestrator's format."""
    import json

    from repro.obs import validate_audit_report, validate_snapshot

    failures = []

    def check(name, fn):
        try:
            fn()
        except Exception as e:
            failures.append((name, repr(e)))

    def snapshots_of(path):
        with open(path) as f:
            doc = json.load(f)
        found = 0
        for res in doc.get("results", {}).values():
            if isinstance(res, dict) and "telemetry" in res:
                validate_snapshot(res["telemetry"])
                found += 1
        if "telemetry" in doc.get("results", {}):
            validate_snapshot(doc["results"]["telemetry"])
        if not found:
            raise ValueError(f"no telemetry snapshots in {path}")

    def chrome_trace_ok(path):
        with open(path) as f:
            doc = json.load(f)
        evs = doc["traceEvents"]
        if not isinstance(evs, list) or not evs:
            raise ValueError("empty traceEvents")
        for ev in evs:
            if ev["ph"] not in ("X", "M"):
                raise ValueError(f"unexpected phase {ev['ph']!r}")
            if ev["ph"] == "X" and (ev["dur"] < 0 or ev["ts"] < 0):
                raise ValueError(f"negative ts/dur in {ev}")

    def control_stages_ok(path):
        """The adaptive-serving stages must have run and their invariants
        must hold: no shedding at/below capacity, shedding engaged (and
        every non-shed answer oracle-identical) at 2x capacity, and the
        warmed post-swap hit rate at least matching the cold one."""
        with open(path) as f:
            doc = json.load(f)
        res = doc.get("results", {})
        for key in ("slo", "overload", "warming"):
            if key not in res:
                raise ValueError(f"no {key!r} stage in {path}")
        slo = res["slo"]
        if slo["shed"] != 0:
            raise ValueError(f"slo stage shed {slo['shed']} queries at "
                             f"offered load <= capacity")
        ov = res["overload"]
        if ov["underload_shed"] != 0:
            raise ValueError(f"shed {ov['underload_shed']} queries at "
                             f"0.5x capacity")
        if not ov["answers_match_oracle"] or not ov["underload"][
                "answers_match_oracle"]:
            raise ValueError("non-shed answers diverged from the "
                             "single-host oracle under overload")
        if not isinstance(ov["shed_ratio"], (int, float)):
            raise ValueError(f"bad overload shed_ratio {ov['shed_ratio']!r}")
        wm = res["warming"]
        if wm["warm_hit_rate"] < wm["cold_hit_rate"]:
            raise ValueError(
                f"warming hurt the post-swap hit rate: warmed "
                f"{wm['warm_hit_rate']} < cold {wm['cold_hit_rate']}")

    def rpc_stage_ok(path):
        """The multi-process RPC stages must have run, answered
        bit-identically to the single-process oracle, actually moved
        digest bytes over the wire, demonstrated admission/execution
        overlap, and embedded a valid ``repro.service.stats/1`` doc."""
        from repro.service import validate_stats
        with open(path) as f:
            doc = json.load(f)
        res = doc.get("results", {})
        for key in ("rpc", "rpc_async"):
            if key not in res:
                raise ValueError(f"no {key!r} stage in {path}")
        rpc = res["rpc"]
        if not rpc["answers_match"]:
            raise ValueError("rpc answers diverged from the "
                             "single-process oracle")
        if rpc["shards"] > 1 and rpc["digest_wire_kb"] <= 0:
            raise ValueError("multi-shard rpc run shipped no digest "
                             "bytes over the wire")
        if rpc["roundtrips"] <= 0:
            raise ValueError("no rpc round-trips recorded")
        stats = rpc.get("stats")
        validate_stats(stats)
        if stats.get("transport") != "rpc":
            raise ValueError(
                f"expected transport 'rpc' in embedded stats, "
                f"got {stats.get('transport')!r}")
        a = res["rpc_async"]
        if not a["answers_match"]:
            raise ValueError("async rpc answers diverged from the "
                             "single-process oracle")
        if not a["overlap_s"] > 0:
            raise ValueError(
                f"submit() showed no admission/execution overlap "
                f"(overlap_s={a['overlap_s']!r})")

    def stats_schema_ok(path):
        """Every service stats document a suite embedded must validate
        against the versioned ``repro.service.stats/1`` schema."""
        from repro.service import validate_stats
        with open(path) as f:
            doc = json.load(f)
        found = 0
        for res in doc.get("results", {}).values():
            if isinstance(res, dict) and isinstance(res.get("stats"),
                                                    dict) \
                    and "schema" in res["stats"]:
                validate_stats(res["stats"])
                found += 1
        if not found:
            raise ValueError(f"no versioned stats documents in {path}")

    def parallel_speedup_ok(path):
        with open(path) as f:
            doc = json.load(f)
        sp = doc.get("parallel_speedup")
        if not isinstance(sp, (int, float)) or sp <= 0:
            raise ValueError(
                f"missing/invalid parallel_speedup in {path}: {sp!r}")
        if not doc.get("parallel", {}).get("rows"):
            raise ValueError(f"no parallel scaling rows in {path}")

    audits = {}

    def _walk_extras(doc):
        """Every snapshot ``extra`` section embedded in a bench JSON."""
        if isinstance(doc, dict):
            if doc.get("schema") == "repro.obs/1" and "extra" in doc:
                yield doc["extra"]
            else:
                for v in doc.values():
                    yield from _walk_extras(v)
        elif isinstance(doc, list):
            for v in doc:
                yield from _walk_extras(v)

    def audits_and_shadow_of(name, path):
        with open(path) as f:
            doc = json.load(f)
        found = []
        for extra in _walk_extras(doc):
            audit = extra.get("audit")
            if audit is not None:
                validate_audit_report(audit)
                found.append(audit)
            shadow = extra.get("shadow")
            if shadow is not None and shadow.get("divergent", 0) != 0:
                raise ValueError(
                    f"shadow verifier diverged in {path}: {shadow}")
        if not found:
            raise ValueError(f"no audit reports embedded in {path}")
        audits[name] = found

    if "build_backends" in ran:
        check("build_backends:parallel_speedup", lambda: parallel_speedup_ok(
            os.path.join(ART, "indexing.json")))
    if "service" in ran:
        check("service:telemetry",
              lambda: snapshots_of(os.path.join(ART, "service.json")))
        check("service:audit", lambda: audits_and_shadow_of(
            "service", os.path.join(ART, "service.json")))
    if "sharded" in ran:
        check("sharded:telemetry",
              lambda: snapshots_of(os.path.join(ART, "sharded.json")))
        check("sharded:trace", lambda: chrome_trace_ok(
            os.path.join(ART, "sharded_trace.json")))
        check("sharded:audit", lambda: audits_and_shadow_of(
            "sharded", os.path.join(ART, "sharded.json")))
        check("sharded:control", lambda: control_stages_ok(
            os.path.join(ART, "sharded.json")))
        check("sharded:rpc", lambda: rpc_stage_ok(
            os.path.join(ART, "sharded.json")))
        check("sharded:stats_schema", lambda: stats_schema_ok(
            os.path.join(ART, "sharded.json")))
    if audits:
        with open(os.path.join(ART, "audit.json"), "w") as f:
            json.dump(dict(suites=audits), f, indent=2)
    return failures


if __name__ == "__main__":
    main()
