"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--smoke] [--only NAME]

(also runnable as ``python benchmarks/run.py``: the shim below puts the
repo root and ``src/`` on ``sys.path`` — what the CI smoke job invokes.)

Default is quick mode (scaled-down graphs, single-core container);
``--full`` runs paper-scale sweeps; ``--smoke`` runs every registered
suite at tiny sizes — it exists to fail on crash and keep per-PR JSON
artifacts flowing, not to produce meaningful numbers. CSVs (and the
JSON artifacts some suites emit) land in benchmarks/artifacts/.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):                     # script execution
    _HERE = os.path.dirname(os.path.abspath(__file__))
    _ROOT = os.path.dirname(_HERE)
    for p in (_ROOT, os.path.join(_ROOT, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)
    __package__ = "benchmarks"

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, every suite; fails on crash")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args(argv)
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    quick = not args.full
    smoke = args.smoke
    os.makedirs(ART, exist_ok=True)

    from . import (bench_delta, bench_device, bench_graph_chars,
                   bench_indexing, bench_k, bench_query, bench_scalability,
                   bench_service, bench_sharded, bench_systems)

    suites = {
        "indexing": lambda: bench_indexing.run(quick, smoke),
        "build_backends": lambda: bench_indexing.run_backends(quick, smoke),
        "pruning": lambda: bench_indexing.run_pruning_ablation(smoke),
        "delta": lambda: bench_delta.run(quick, smoke),
        "query": lambda: bench_query.run(quick, smoke),
        "k": lambda: bench_k.run(quick, smoke),
        "graph_chars": lambda: bench_graph_chars.run(quick, smoke),
        "scalability": lambda: bench_scalability.run(quick, smoke),
        "systems": lambda: bench_systems.run(quick, smoke),
        "device": lambda: bench_device.run(quick, smoke),
        "service": lambda: bench_service.run(quick, smoke),
        "sharded": lambda: bench_sharded.run(quick, smoke),
    }
    failures = []
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            rep = fn()
            csv = rep.to_csv()
            with open(os.path.join(ART, f"{rep.name}.csv"), "w") as f:
                f.write(csv)
            print(f"===== {name} done in {time.time()-t0:.1f}s "
                  f"({len(rep.rows)} rows) =====", flush=True)
        except Exception as e:  # pragma: no cover
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print("\nFAILED suites:", failures)
        sys.exit(1)
    print("\nAll benchmark suites completed.")


if __name__ == "__main__":
    main()
