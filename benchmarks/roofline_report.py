"""Render §Dry-run / §Roofline markdown tables from the dry-run artifacts
(benchmarks/artifacts/dryrun/*.json)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_records(art_dir: str = ART_DIR) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        r["_file"] = os.path.basename(path)
        recs.append(r)
    return recs


def _fmt_bytes(b) -> str:
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def roofline_table(recs: List[Dict], mesh: str = "pod") -> str:
    """One row per (arch x shape): the §Roofline table."""
    rows = [
        "| arch | shape | status | compute (s) | memory (s) | coll (s) |"
        " dominant | roofline frac | MODEL/HLO flops | HBM GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if mesh not in r.get("_file", ""):
            continue
        if r.get("status") == "skipped" or r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - |"
                        f" - | - | - | - |")
            continue
        if r.get("status") == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - |"
                        f" - | - | - | - | - |")
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['dominant'].replace('_s','')} "
            f"| {t['roofline_fraction']:.3f} "
            f"| {r.get('useful_flops_ratio', 0):.2f} "
            f"| {_fmt_bytes(r['memory']['peak_bytes_per_dev'])} |")
    return "\n".join(rows)


def dryrun_summary(recs: List[Dict]) -> str:
    ok = sum(1 for r in recs if r.get("status") == "ok")
    skip = sum(1 for r in recs if r.get("status") == "skipped")
    err = sum(1 for r in recs if r.get("status") == "error")
    lines = [f"cells: {len(recs)}  ok: {ok}  skipped: {skip}  "
             f"errors: {err}"]
    for r in recs:
        if r.get("status") == "error":
            lines.append(f"  ERROR {r['_file']}: {r.get('error','')[:160]}")
    return "\n".join(lines)


def main():
    recs = load_records()
    print(dryrun_summary(recs))
    for mesh in ("pod", "multipod"):
        sub = [r for r in recs if f"__{mesh}" in r.get("_file", "")]
        if sub:
            print(f"\n## Roofline — {mesh} mesh\n")
            print(roofline_table(recs, mesh=f"__{mesh}"))


if __name__ == "__main__":
    main()
