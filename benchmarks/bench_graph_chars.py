"""Paper Fig. 5: impact of label-set size |L| and average degree d on
ER- and BA-graphs (indexing time / index size / query time)."""
from __future__ import annotations

import time

from repro.core.index_builder import build_rlc_index
from repro.core.queries import generate_queries
from repro.graphgen import barabasi_albert, erdos_renyi

from .common import Report, timeit


def run(quick: bool = True, smoke: bool = False, k: int = 2) -> Report:
    rep = Report("graph_chars.fig5")
    n = 400 if quick else 2000
    degrees = (2, 4) if quick else (2, 3, 4, 5)
    labels = (8, 16) if quick else (8, 12, 16, 20, 24, 28, 32, 36)
    n_q = 100 if quick else 1000
    if smoke:
        n, degrees, labels, n_q = 120, (2,), (8,), 40
    for fam, gen in (("ER", erdos_renyi),
                     ("BA", lambda v, d, l, seed=0: barabasi_albert(
                         v, max(1, int(d / 2)), l, seed))):
        for d in degrees:
            for nl in labels:
                g = gen(n, d, nl, seed=7)
                t0 = time.perf_counter()
                idx = build_rlc_index(g, k)
                it = time.perf_counter() - t0
                qs = generate_queries(g, k, n_true=n_q, n_false=n_q,
                                      seed=3)
                tq = timeit(lambda: [idx.query(s, t, L)
                                     for s, t, L, _ in qs.all()])
                rep.add(family=fam, V=g.num_vertices, E=g.num_edges,
                        d=d, L=nl, it_s=round(it, 3),
                        is_bytes=idx.size_bytes(),
                        query_ms=round(tq * 1e3, 2),
                        n_true=len(qs.true_queries),
                        n_false=len(qs.false_queries))
    return rep
