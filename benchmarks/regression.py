"""Perf-regression gate over the benchmark JSON artifacts.

``benchmarks/baselines.json`` pins the headline numbers of a known-good
smoke run (distilled by ``python -m benchmarks.regression --update``);
after each smoke run the gate re-reads the fresh artifacts and compares
every pinned metric against its baseline with a warn-then-fail
tolerance ladder:

* within ``warn_ratio`` (default 1.6x worse) — ok;
* worse than ``warn_ratio`` but within ``fail_ratio`` (default 8x) —
  warn: printed, recorded in ``artifacts/regression.json``, build
  passes (smoke boxes are noisy; an 8x cliff is a real regression, a
  2x wobble on a 300-request run is weather);
* worse than ``fail_ratio`` — fail: the orchestrator exits non-zero;
* metric missing from fresh artifacts — fail (a silently dropped
  benchmark stage must not pass the gate).

Ratios are overridable per run via ``RLC_BENCH_WARN_RATIO`` /
``RLC_BENCH_FAIL_RATIO`` (CI smoke boxes vs local laptops differ).
Direction matters: for ``higher``-is-better metrics the worse-ratio is
``baseline / fresh``; for ``lower``-is-better it is
``fresh / baseline`` — a *better* fresh number never warns.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional, Tuple

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "artifacts")
BASELINES_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "baselines.json")
BASELINES_SCHEMA = "repro.bench.baselines/1"

DEFAULT_WARN_RATIO = 1.6
DEFAULT_FAIL_RATIO = 8.0

#: (artifact file, path into its JSON, direction). The headline numbers
#: of each serving/build suite — few enough to stay below the noise
#: floor arguments, meaningful enough that an 8x cliff in any of them is
#: a real regression.
METRICS: List[Tuple[str, Tuple[str, ...], str]] = [
    ("service.json", ("results", "sorted", "qps"), "higher"),
    ("service.json", ("results", "numpy", "qps"), "higher"),
    ("service.json", ("results", "cache_4096", "hit_rate"), "higher"),
    ("sharded.json", ("results", "shards_2", "qps"), "higher"),
    ("sharded.json", ("results", "hot_swap", "swap_s"), "lower"),
    # control plane: tail ratio under an SLO target, shedding engaged
    # under 2x-capacity overload, post-swap warmed hit rate
    ("sharded.json", ("results", "slo", "p99_over_p50"), "lower"),
    ("sharded.json", ("results", "overload", "shed_ratio"), "higher"),
    ("sharded.json", ("results", "warming", "warm_hit_rate"), "higher"),
    # multi-process rpc transport: end-to-end throughput over the wire,
    # per-call round-trip tail, and the digest bytes a stream ships
    # (bytes regressing means the digest hand-off got chattier)
    ("sharded.json", ("results", "rpc", "qps"), "higher"),
    ("sharded.json", ("results", "rpc", "roundtrip_p99_us"), "lower"),
    ("sharded.json", ("results", "rpc", "digest_wire_kb"), "lower"),
    ("indexing.json", ("aggregate_s", "numpy"), "lower"),
    ("indexing.json", ("numpy_aggregate_speedup",), "higher"),
    ("indexing.json", ("parallel_speedup",), "higher"),
    ("delta.json", ("best_single_speedup",), "higher"),
]


def _metric_id(artifact: str, path: Tuple[str, ...]) -> str:
    stem = artifact.rsplit(".", 1)[0]
    return f"{stem}:{'.'.join(path)}"


def _dig(doc, path: Tuple[str, ...]):
    for key in path:
        if not isinstance(doc, dict) or key not in doc:
            return None
        doc = doc[key]
    return doc


def _read_metric(art_dir: str, artifact: str,
                 path: Tuple[str, ...]) -> Optional[float]:
    fp = os.path.join(art_dir, artifact)
    if not os.path.exists(fp):
        return None
    with open(fp) as f:
        doc = json.load(f)
    v = _dig(doc, path)
    return float(v) if isinstance(v, (int, float)) else None


def distill(art_dir: str = ART) -> dict:
    """Condense the current artifacts into a committable baselines doc."""
    metrics = {}
    for artifact, path, direction in METRICS:
        v = _read_metric(art_dir, artifact, path)
        if v is None:
            continue
        metrics[_metric_id(artifact, path)] = dict(
            value=v, direction=direction, artifact=artifact,
            path=list(path))
    return dict(schema=BASELINES_SCHEMA, mode="smoke", metrics=metrics)


def load_baselines(path: str = BASELINES_PATH) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != BASELINES_SCHEMA:
        raise ValueError(
            f"{path}: expected schema {BASELINES_SCHEMA!r}, "
            f"got {doc.get('schema')!r}")
    return doc


def compare(art_dir: str, baselines: dict,
            warn_ratio: Optional[float] = None,
            fail_ratio: Optional[float] = None) -> dict:
    """Fresh artifacts vs baselines; returns the verdict document."""
    warn_ratio = float(os.environ.get("RLC_BENCH_WARN_RATIO",
                                      warn_ratio or DEFAULT_WARN_RATIO))
    fail_ratio = float(os.environ.get("RLC_BENCH_FAIL_RATIO",
                                      fail_ratio or DEFAULT_FAIL_RATIO))
    rows = []
    for mid, base in baselines.get("metrics", {}).items():
        fresh = _read_metric(art_dir, base["artifact"],
                             tuple(base["path"]))
        row = dict(metric=mid, direction=base["direction"],
                   baseline=base["value"], fresh=fresh)
        if fresh is None:
            row.update(status="missing",
                       note="metric absent from fresh artifacts")
        else:
            bv, fv = float(base["value"]), float(fresh)
            if base["direction"] == "higher":
                worse = bv / fv if fv > 0 else float("inf")
            else:
                worse = fv / bv if bv > 0 else float("inf")
            row["worse_ratio"] = round(worse, 3)
            row["status"] = ("fail" if worse > fail_ratio
                             else "warn" if worse > warn_ratio else "ok")
        rows.append(row)
    statuses = [r["status"] for r in rows]
    return dict(
        schema="repro.bench.regression/1",
        warn_ratio=warn_ratio, fail_ratio=fail_ratio,
        metrics=rows,
        ok=sum(s == "ok" for s in statuses),
        warned=sum(s == "warn" for s in statuses),
        failed=sum(s in ("fail", "missing") for s in statuses),
    )


def gate(art_dir: str = ART,
         baselines_path: str = BASELINES_PATH) -> List[Tuple[str, str]]:
    """Run the gate after a smoke run; returns orchestrator-format
    ``(name, error)`` failures (warns print but pass) and writes the
    verdict to ``artifacts/regression.json``."""
    baselines = load_baselines(baselines_path)
    if baselines is None:
        print(f"regression gate: no baselines at {baselines_path}; "
              f"run `python -m benchmarks.regression --update` after a "
              f"known-good smoke run to create them")
        return []
    verdict = compare(art_dir, baselines)
    os.makedirs(art_dir, exist_ok=True)
    with open(os.path.join(art_dir, "regression.json"), "w") as f:
        json.dump(verdict, f, indent=2)
    failures = []
    for row in verdict["metrics"]:
        if row["status"] == "ok":
            continue
        msg = (f"{row['metric']}: baseline={row['baseline']:g} "
               f"fresh={row['fresh'] if row['fresh'] is None else round(row['fresh'], 4)} "
               f"({row.get('worse_ratio', '-')}x worse, "
               f"{row['direction']}-is-better)")
        if row["status"] == "warn":
            print(f"regression gate WARN {msg}")
        else:
            print(f"regression gate FAIL {msg}")
            failures.append((f"regression:{row['metric']}",
                             row.get("note", msg)))
    print(f"regression gate: {verdict['ok']} ok, "
          f"{verdict['warned']} warned, {verdict['failed']} failed "
          f"(warn>{verdict['warn_ratio']}x, fail>{verdict['fail_ratio']}x)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.regression",
        description="compare bench artifacts against pinned baselines")
    ap.add_argument("--update", action="store_true",
                    help="re-distill baselines.json from the current "
                         "artifacts instead of gating")
    ap.add_argument("--art-dir", default=ART)
    args = ap.parse_args(argv)
    if args.update:
        doc = distill(args.art_dir)
        if not doc["metrics"]:
            print(f"no gateable metrics found in {args.art_dir}; run the "
                  f"benchmarks first")
            return 1
        with open(BASELINES_PATH, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"wrote {BASELINES_PATH} ({len(doc['metrics'])} metrics)")
        return 0
    failures = gate(args.art_dir)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
