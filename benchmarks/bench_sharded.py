"""Sharded-serving benchmark: throughput and latency vs shard count.

Same Zipf request workload as :mod:`benchmarks.bench_service`, served by
:class:`repro.service.sharded.ShardedRLCService` at shard counts 1/2/4/8
(x replicas where requested). Reported per shard count: per-query p50/p99
latency, throughput, cache hit-rate, local-route ratio, shipped digest
bytes, and the shard plan's entry balance — the numbers that show what
two-sided routing costs (cross-shard hops) and buys (per-host index
slices shrink ~1/S while answers stay bit-identical).

Every measured serve window is preceded by an unmeasured warmup pass
(``common.warm_service``): the first batch at each jit shape pays XLA
compile time, which used to surface as a ~350ms ``exec_p99_us`` outlier;
the compile cost is now its own per-row artifact field (``compile_s``).

One hot-swap row measures the rolling-rebuild pause at the largest shard
count. Two telemetry stages close the run: an on/off pair quantifying the
registry's counter overhead (throughput with ``telemetry=False`` vs the
default-on counters), and a tracing-enabled run whose sampled spans
decompose p99 latency into queue-wait / route / executor components and
export a Chrome ``trace_event`` timeline.

Three control-plane stages exercise :mod:`repro.service.control`:

* ``slo`` — serving with ``target_p99_ms`` set; records steady-state
  q_p99 / q_p50 and the shed count (must be 0 at offered <= capacity).
* ``overload`` — open-loop arrivals on a :class:`VirtualClock` at 0.5x
  and 2x the service's measured virtual capacity; records the shed
  ratio, a p99-vs-SLO verdict, and an oracle check that every non-shed
  answer is bit-identical to the single-host service.
* ``warming`` — identical hot-swap runs with prioritized cache warming
  off vs on; records the cache hit rate over the first 100 post-swap
  requests for each.

Two RPC stages measure true multi-process serving
(``transport="rpc"``): ``rpc`` serves the stream through shard-host
worker processes — per-call wire round-trip p50/p99, digest bytes on
the wire, and an oracle check that every answer is bit-identical to the
single-process service; ``rpc_async`` pushes the same stream through
non-blocking ``submit()`` futures and records the engine's overlap
ledger (``overlap_s`` — execution time spent while admission was still
running). Both embed the validated ``repro.service.stats/1`` document.

Writes the orchestrator CSV plus JSON artifacts alongside
``service.json``: ``benchmarks/artifacts/sharded.json`` (rows + stats +
telemetry snapshot), ``sharded_trace.json`` (Chrome trace — load in
``chrome://tracing`` / Perfetto), and ``sharded.prom`` (Prometheus text
format).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.queries import biased_true_queries
from repro.graphgen import erdos_renyi
from repro.service import RLCService, ServiceConfig, SHED, VirtualClock
from repro.service.sharded import ShardedRLCService, ShardedServiceConfig

from .common import (Report, hist_summary_us, run_query_stream,
                     warm_service, zipf_weights)

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def run(quick: bool = True, smoke: bool = False, k: int = 2) -> Report:
    rep = Report("sharded")
    n = 400 if quick else 4000
    n_pool = 240 if quick else 1200
    n_requests = 3000 if quick else 30000
    shard_counts = (1, 2, 4, 8)
    num_replicas = 2
    if smoke:
        n, n_pool, n_requests = 160, 60, 300
        shard_counts, num_replicas = (1, 2), 1
    g = erdos_renyi(n, 3.5, 4, seed=31)

    t0 = time.perf_counter()
    base = RLCService.build(g, ServiceConfig(k=k))
    rep.add(stage="build", V=n, E=g.num_edges, k=k,
            entries=base.index.num_entries(),
            seconds=round(time.perf_counter() - t0, 3))

    qs = biased_true_queries(g, k, n=n_pool // 2, seed=5)
    pool = qs.true_queries + qs.false_queries
    rng = np.random.default_rng(17)
    rng.shuffle(pool)
    stream = [pool[i] for i in rng.choice(
        len(pool), size=n_requests, p=zipf_weights(len(pool)))]

    # shadow verification rides the per-shard-count serve runs; the
    # pending checks drain at snapshot time (off the timed stream) and
    # run.py fails the smoke gate on any divergence
    shadow_rate = 0.1 if smoke else 0.02
    results = {}
    for S in shard_counts:
        t0 = time.perf_counter()
        svc = ShardedRLCService.build(
            g, ShardedServiceConfig(
                k=k, batch_size=32, max_wait_ms=2.0, cache_capacity=1024,
                num_shards=S, num_replicas=num_replicas,
                shadow_sample_rate=shadow_rate),
            index=base.index)
        shard_build_s = time.perf_counter() - t0
        warm = warm_service(svc, stream[:500], chunk=64)
        lat = run_query_stream(svc, stream, chunk=64)
        st = svc.stats()
        queue = hist_summary_us(svc.obs.registry,
                                "rlc_batcher_queue_wait_seconds")
        comp = hist_summary_us(svc.obs.registry,
                               "rlc_executor_batch_seconds")
        row = dict(
            stage="serve", shards=S, replicas=num_replicas,
            requests=len(stream),
            q_p50_us=round(float(np.percentile(lat, 50)) * 1e6, 1),
            q_p99_us=round(float(np.percentile(lat, 99)) * 1e6, 1),
            queue_p50_us=queue["p50_us"], queue_p99_us=queue["p99_us"],
            exec_p50_us=comp["p50_us"], exec_p99_us=comp["p99_us"],
            qps=round(len(stream) / lat.sum(), 1),
            cache_hit_rate=round(st["cache"]["hit_rate"], 4),
            local_ratio=st["router"]["local_ratio"],
            digest_kb=round(st["executor"]["digest_bytes"] / 1024, 1),
            plan_balance=st["index"]["plan"]["balance"],
            max_shard_bytes=max(sh["size_bytes"] for sh in st["shards"]),
            shard_build_s=round(shard_build_s, 3),
            warmup_s=warm["warm_s"], compile_s=warm["compile_s"],
        )
        rep.add(**row)
        svc.audit_report(sample=64)    # embedded via snapshot extra
        results[f"shards_{S}"] = dict(row, stats=st,
                                      telemetry=svc.telemetry_snapshot())

    # hot-swap pause at the largest shard count: time the rolling rebuild
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=k, batch_size=32, cache_capacity=1024,
                                num_shards=shard_counts[-1],
                                num_replicas=num_replicas),
        index=base.index)
    run_query_stream(svc, stream[:500], chunk=64)     # warm
    t0 = time.perf_counter()
    svc.hot_swap()                               # re-freeze + swap all shards
    swap_s = time.perf_counter() - t0
    lat = run_query_stream(svc, stream[:1000], chunk=64)
    rep.add(stage="hot_swap", shards=shard_counts[-1],
            replicas=num_replicas, swap_s=round(swap_s, 3),
            post_swap_p50_us=round(float(np.percentile(lat, 50)) * 1e6, 1))
    results["hot_swap"] = dict(shards=shard_counts[-1], swap_s=swap_s)

    # -- telemetry overhead: identical runs with counters off vs on ------ #
    S = shard_counts[-1]
    qps = {}
    for telemetry in (False, True):
        svc = ShardedRLCService.build(
            g, ShardedServiceConfig(k=k, batch_size=32, max_wait_ms=2.0,
                                    cache_capacity=1024, num_shards=S,
                                    num_replicas=num_replicas,
                                    telemetry=telemetry),
            index=base.index)
        run_query_stream(svc, stream[:500], chunk=64)  # warm cache + jit
        lat = run_query_stream(svc, stream, chunk=64)
        qps[telemetry] = len(stream) / float(lat.sum())
    overhead = 1.0 - qps[True] / qps[False]
    rep.add(stage="telemetry_overhead", shards=S,
            qps_off=round(qps[False], 1), qps_on=round(qps[True], 1),
            overhead_frac=round(overhead, 4))
    results["telemetry_overhead"] = dict(
        shards=S, qps_off=qps[False], qps_on=qps[True],
        overhead_frac=overhead)

    # -- tracing-enabled run: spans -> latency decomposition + exports -- #
    sample_rate = 1.0 if smoke else 0.05
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=k, batch_size=32, max_wait_ms=2.0,
                                cache_capacity=1024, num_shards=S,
                                num_replicas=num_replicas,
                                trace_sample_rate=sample_rate),
        index=base.index)
    lat = run_query_stream(svc, stream, chunk=64)
    reg = svc.obs.registry
    decomposition = dict(
        q_p50_us=round(float(np.percentile(lat, 50)) * 1e6, 1),
        q_p99_us=round(float(np.percentile(lat, 99)) * 1e6, 1),
        queue_wait=hist_summary_us(reg, "rlc_batcher_queue_wait_seconds"),
        route_local=hist_summary_us(reg, "rlc_fanout_subbatch_seconds",
                                    dict(path="local")),
        route_remote=hist_summary_us(reg, "rlc_fanout_subbatch_seconds",
                                     dict(path="remote")),
        executor=hist_summary_us(reg, "rlc_executor_batch_seconds"))
    results["latency_decomposition"] = decomposition
    results["telemetry"] = svc.telemetry_snapshot(
        extra=dict(latency_decomposition=decomposition))
    results["tracing"] = svc.obs.tracer.stats()   # includes sample_rate
    trace = svc.chrome_trace()
    rep.add(stage="tracing", shards=S, sample_rate=sample_rate,
            spans=len(trace["traceEvents"]) - 1,
            queue_p99_us=decomposition["queue_wait"]["p99_us"],
            exec_p99_us=decomposition["executor"]["p99_us"])

    # -- slo: closed-loop batching against a latency target -------------- #
    slo_ms = 25.0
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=k, batch_size=32, max_wait_ms=2.0,
                                cache_capacity=1024, num_shards=S,
                                num_replicas=num_replicas,
                                target_p99_ms=slo_ms),
        index=base.index)
    warm_service(svc, stream[:500], chunk=64)
    lat = run_query_stream(svc, stream, chunk=64)
    st = svc.stats()
    q50 = float(np.percentile(lat, 50))
    q99 = float(np.percentile(lat, 99))
    ratio = q99 / q50 if q50 > 0 else float("inf")
    slo_row = dict(stage="slo", shards=S, target_p99_ms=slo_ms,
                   q_p50_us=round(q50 * 1e6, 1),
                   q_p99_us=round(q99 * 1e6, 1),
                   p99_over_p50=round(ratio, 2),
                   tail_ok=bool(ratio <= 3.0),
                   shed=st["queries_shed"],
                   qps=round(len(stream) / float(lat.sum()), 1))
    rep.add(**slo_row)
    results["slo"] = dict(slo_row, control=st["control"])

    # -- overload: open-loop virtual-clock arrivals vs admission control - #
    # Virtual capacity probe: unpaced stream on a virtual clock; only
    # executor time advances it, so requests/virtual-second is the
    # service's intrinsic drain rate, independent of driver overhead.
    def control_service(vclock):
        svc = ShardedRLCService.build(
            g, ShardedServiceConfig(k=k, batch_size=32, max_wait_ms=2.0,
                                    cache_capacity=1024, num_shards=S,
                                    num_replicas=num_replicas,
                                    target_p99_ms=slo_ms,
                                    admission_max_pending=256,
                                    # shed once queue wait alone eats the
                                    # whole latency target
                                    admission_backpressure_ms=slo_ms,
                                    clock=vclock),
            index=base.index)
        warm_service(svc, stream[:500], chunk=64)
        return svc

    vclock = VirtualClock()
    svc = control_service(vclock)
    t0v = vclock()
    run_query_stream(svc, stream, chunk=64)
    virtual_s = max(vclock() - t0v, 1e-9)
    cap_qps = len(stream) / virtual_s

    # the back-pressure EWMA needs a few dozen executed batches of
    # sustained lateness to cross its threshold; tile the smoke stream so
    # the overload window is long enough to reach steady state
    ostream = stream * max(1, -(-1500 // len(stream)))
    truth = [bool(a) for a in base.query_batch(ostream)]
    ov = {}
    for label, factor in (("underload", 0.5), ("overload", 2.0)):
        vclock = VirtualClock()
        svc = control_service(vclock)
        offered = factor * cap_qps
        t0v = vclock()
        answers = []
        chunk = 16
        for i in range(0, len(ostream), chunk):
            # open-loop replay: requests are stamped with their scheduled
            # arrival time. When the service runs behind (executor time
            # advanced the virtual clock past the schedule), the lateness
            # shows up as queue wait at flush — exactly what the
            # admission controller's back-pressure EWMA watches.
            stamp = t0v + i / offered
            vclock.at_least(stamp)
            answers.extend(svc.query_batch(ostream[i:i + chunk], now=stamp))
        st = svc.stats()
        shed = st["queries_shed"]
        match = all(a is SHED or bool(a) == truth[idx]
                    for idx, a in enumerate(answers))
        queue = hist_summary_us(svc.obs.registry,
                                "rlc_batcher_queue_wait_seconds")
        comp = hist_summary_us(svc.obs.registry,
                               "rlc_executor_batch_seconds")
        p99_us = queue["p99_us"] + comp["p99_us"]
        ov[label] = dict(
            offered_x=factor, offered_qps=round(offered, 1),
            requests=len(ostream), shed=shed,
            shed_ratio=round(shed / len(ostream), 4),
            queue_p99_us=queue["p99_us"], exec_p99_us=comp["p99_us"],
            p99_ms=round(p99_us / 1e3, 3),
            slo_verdict=("met" if p99_us <= slo_ms * 1e3 else "violated"),
            answers_match_oracle=match,
            admission=st["control"]["admission"])
        row = {kk: vv for kk, vv in ov[label].items() if kk != "admission"}
        rep.add(stage="overload", label=label, shards=S, **row)
    results["overload"] = dict(
        ov["overload"], target_p99_ms=slo_ms,
        capacity_qps=round(cap_qps, 1),
        underload_shed=ov["underload"]["shed"],
        underload=ov["underload"])

    # -- warming: post-hot-swap hit rate, warmer off vs on ---------------- #
    first_n = 100
    wm = {}
    for label, warm_cap in (("cold", 0), ("warmed", 256)):
        svc = ShardedRLCService.build(
            g, ShardedServiceConfig(k=k, batch_size=32, max_wait_ms=2.0,
                                    cache_capacity=1024, num_shards=S,
                                    num_replicas=num_replicas,
                                    warm_capacity=warm_cap,
                                    admission_max_pending=10 ** 6),
            index=base.index)
        run_query_stream(svc, stream, chunk=64)   # populate sketch + cache
        svc.hot_swap()       # clears the cache; warmer (if on) refills it
        h0, l0 = svc.cache.stats.hits, svc.cache.stats.lookups
        run_query_stream(svc, stream[:first_n], chunk=50)
        dl = svc.cache.stats.lookups - l0
        hr = (svc.cache.stats.hits - h0) / dl if dl else 0.0
        ctl_stats = svc.stats()["control"]
        warm_stats = ctl_stats.get("warmer") if ctl_stats else None
        wm[label] = dict(warm_capacity=warm_cap,
                         first_queries=first_n,
                         first_hit_rate=round(hr, 4),
                         warmer=warm_stats)
        rep.add(stage="warming", label=label, shards=S,
                warm_capacity=warm_cap, first_hit_rate=wm[label]["first_hit_rate"])
    results["warming"] = dict(
        first_queries=first_n,
        cold_hit_rate=wm["cold"]["first_hit_rate"],
        warm_hit_rate=wm["warmed"]["first_hit_rate"],
        warming_helps=wm["warmed"]["first_hit_rate"]
        > wm["cold"]["first_hit_rate"],
        warmer=wm["warmed"]["warmer"])

    # -- rpc: true multi-process shard serving + async admission overlap - #
    # One worker process per (shard, replica), answers over the wire;
    # oracle-checked bit-identical to the single-process service. The
    # async substage submits the stream through ``submit()`` futures and
    # records the engine's overlap ledger — execution time spent while
    # admission was still running, the observable proof that submit()
    # actually overlaps admission with execution.
    rpc_shards = 2 if smoke else 4
    rpc_replicas = 1 if smoke else 2
    rpc_stream = stream if smoke else stream[:2000]
    truth_rpc = [bool(a) for a in base.query_batch(rpc_stream)]
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=k, batch_size=32, max_wait_ms=2.0,
                                cache_capacity=1024, use_device=False,
                                num_shards=rpc_shards,
                                num_replicas=rpc_replicas,
                                transport="rpc"),
        index=base.index)
    lat = run_query_stream(svc, rpc_stream, chunk=64)
    svc.cache.clear()
    sync_answers = svc.query_batch(rpc_stream)
    rpc_match = all(bool(a) == truth_rpc[i]
                    for i, a in enumerate(sync_answers))
    rt = hist_summary_us(svc.obs.registry, "rlc_rpc_roundtrip_seconds")
    st = svc.stats()
    from repro.service import validate_stats
    validate_stats(st)
    rpc_row = dict(
        stage="rpc", shards=rpc_shards, replicas=rpc_replicas,
        requests=len(rpc_stream),
        q_p50_us=round(float(np.percentile(lat, 50)) * 1e6, 1),
        q_p99_us=round(float(np.percentile(lat, 99)) * 1e6, 1),
        qps=round(len(rpc_stream) / lat.sum(), 1),
        roundtrip_p50_us=rt["p50_us"], roundtrip_p99_us=rt["p99_us"],
        roundtrips=rt["count"],
        digest_wire_kb=round(st["executor"]["digest_bytes"] / 1024, 1),
        wire_sent_kb=round(st["rpc"]["wire_bytes"]["sent"] / 1024, 1),
        wire_recv_kb=round(st["rpc"]["wire_bytes"]["received"] / 1024, 1),
        live_workers=st["rpc"]["live_workers"],
        answers_match=rpc_match)
    rep.add(**rpc_row)

    # async substage on the same fleet: clear the cache so every submit
    # reaches the scheduler, then admit the whole stream non-blocking
    svc.cache.clear()
    async_stream = rpc_stream * (3 if smoke else 1)
    svc.start()
    t0 = time.perf_counter()
    futs = [svc.submit(s, t, mr) for s, t, mr in async_stream]
    admit_wall_s = time.perf_counter() - t0
    svc._engine.flush()
    vals = [f.result(timeout=300.0) for f in futs]
    total_wall_s = time.perf_counter() - t0
    async_match = all(bool(v) == truth_rpc[i % len(rpc_stream)]
                      for i, v in enumerate(vals))
    es = svc._engine.stats()
    rpc_stats_doc = svc.stats()
    validate_stats(rpc_stats_doc)
    async_row = dict(
        stage="rpc_async", shards=rpc_shards, replicas=rpc_replicas,
        submitted=es["submitted"], completed=es["completed"],
        exec_batches=es["exec_batches"],
        admit_wall_s=round(admit_wall_s, 4),
        total_wall_s=round(total_wall_s, 4),
        admit_s=es["admit_s"], exec_s=es["exec_s"],
        overlap_s=es["overlap_s"], answers_match=async_match)
    rep.add(**async_row)
    results["rpc"] = dict(rpc_row, stats=rpc_stats_doc,
                          workers=st["rpc"]["workers"])
    results["rpc_async"] = dict(async_row)
    svc.close()

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "sharded_trace.json"), "w") as f:
        json.dump(trace, f)
    with open(os.path.join(ART, "sharded.prom"), "w") as f:
        f.write(svc.prometheus())
    with open(os.path.join(ART, "sharded.json"), "w") as f:
        json.dump(dict(graph=g.summary(), k=k, requests=n_requests,
                       zipf_exponent=1.0, replicas=num_replicas,
                       shard_counts=list(shard_counts), results=results),
                  f, indent=2, default=str)
    return rep
