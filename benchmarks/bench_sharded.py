"""Sharded-serving benchmark: throughput and latency vs shard count.

Same Zipf request workload as :mod:`benchmarks.bench_service`, served by
:class:`repro.service.sharded.ShardedRLCService` at shard counts 1/2/4/8
(x replicas where requested). Reported per shard count: per-query p50/p99
latency, throughput, cache hit-rate, local-route ratio, shipped digest
bytes, and the shard plan's entry balance — the numbers that show what
two-sided routing costs (cross-shard hops) and buys (per-host index
slices shrink ~1/S while answers stay bit-identical).

One hot-swap row measures the rolling-rebuild pause at the largest shard
count. Two telemetry stages close the run: an on/off pair quantifying the
registry's counter overhead (throughput with ``telemetry=False`` vs the
default-on counters), and a tracing-enabled run whose sampled spans
decompose p99 latency into queue-wait / route / executor components and
export a Chrome ``trace_event`` timeline.

Writes the orchestrator CSV plus JSON artifacts alongside
``service.json``: ``benchmarks/artifacts/sharded.json`` (rows + stats +
telemetry snapshot), ``sharded_trace.json`` (Chrome trace — load in
``chrome://tracing`` / Perfetto), and ``sharded.prom`` (Prometheus text
format).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.queries import biased_true_queries
from repro.graphgen import erdos_renyi
from repro.service import RLCService, ServiceConfig
from repro.service.sharded import ShardedRLCService, ShardedServiceConfig

from .common import Report, hist_summary_us, run_query_stream, zipf_weights

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def run(quick: bool = True, smoke: bool = False, k: int = 2) -> Report:
    rep = Report("sharded")
    n = 400 if quick else 4000
    n_pool = 240 if quick else 1200
    n_requests = 3000 if quick else 30000
    shard_counts = (1, 2, 4, 8)
    num_replicas = 2
    if smoke:
        n, n_pool, n_requests = 160, 60, 300
        shard_counts, num_replicas = (1, 2), 1
    g = erdos_renyi(n, 3.5, 4, seed=31)

    t0 = time.perf_counter()
    base = RLCService.build(g, ServiceConfig(k=k))
    rep.add(stage="build", V=n, E=g.num_edges, k=k,
            entries=base.index.num_entries(),
            seconds=round(time.perf_counter() - t0, 3))

    qs = biased_true_queries(g, k, n=n_pool // 2, seed=5)
    pool = qs.true_queries + qs.false_queries
    rng = np.random.default_rng(17)
    rng.shuffle(pool)
    stream = [pool[i] for i in rng.choice(
        len(pool), size=n_requests, p=zipf_weights(len(pool)))]

    # shadow verification rides the per-shard-count serve runs; the
    # pending checks drain at snapshot time (off the timed stream) and
    # run.py fails the smoke gate on any divergence
    shadow_rate = 0.1 if smoke else 0.02
    results = {}
    for S in shard_counts:
        t0 = time.perf_counter()
        svc = ShardedRLCService.build(
            g, ShardedServiceConfig(
                k=k, batch_size=32, max_wait_ms=2.0, cache_capacity=1024,
                num_shards=S, num_replicas=num_replicas,
                shadow_sample_rate=shadow_rate),
            index=base.index)
        shard_build_s = time.perf_counter() - t0
        lat = run_query_stream(svc, stream, chunk=64)
        st = svc.stats()
        queue = hist_summary_us(svc.obs.registry,
                                "rlc_batcher_queue_wait_seconds")
        comp = hist_summary_us(svc.obs.registry,
                               "rlc_executor_batch_seconds")
        row = dict(
            stage="serve", shards=S, replicas=num_replicas,
            requests=len(stream),
            q_p50_us=round(float(np.percentile(lat, 50)) * 1e6, 1),
            q_p99_us=round(float(np.percentile(lat, 99)) * 1e6, 1),
            queue_p50_us=queue["p50_us"], queue_p99_us=queue["p99_us"],
            exec_p50_us=comp["p50_us"], exec_p99_us=comp["p99_us"],
            qps=round(len(stream) / lat.sum(), 1),
            cache_hit_rate=round(st["cache"]["hit_rate"], 4),
            local_ratio=st["router"]["local_ratio"],
            digest_kb=round(st["executor"]["digest_bytes"] / 1024, 1),
            plan_balance=st["index"]["plan"]["balance"],
            max_shard_bytes=max(sh["size_bytes"] for sh in st["shards"]),
            shard_build_s=round(shard_build_s, 3),
        )
        rep.add(**row)
        svc.audit_report(sample=64)    # embedded via snapshot extra
        results[f"shards_{S}"] = dict(row, stats=st,
                                      telemetry=svc.telemetry_snapshot())

    # hot-swap pause at the largest shard count: time the rolling rebuild
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=k, batch_size=32, cache_capacity=1024,
                                num_shards=shard_counts[-1],
                                num_replicas=num_replicas),
        index=base.index)
    run_query_stream(svc, stream[:500], chunk=64)     # warm
    t0 = time.perf_counter()
    svc.hot_swap()                               # re-freeze + swap all shards
    swap_s = time.perf_counter() - t0
    lat = run_query_stream(svc, stream[:1000], chunk=64)
    rep.add(stage="hot_swap", shards=shard_counts[-1],
            replicas=num_replicas, swap_s=round(swap_s, 3),
            post_swap_p50_us=round(float(np.percentile(lat, 50)) * 1e6, 1))
    results["hot_swap"] = dict(shards=shard_counts[-1], swap_s=swap_s)

    # -- telemetry overhead: identical runs with counters off vs on ------ #
    S = shard_counts[-1]
    qps = {}
    for telemetry in (False, True):
        svc = ShardedRLCService.build(
            g, ShardedServiceConfig(k=k, batch_size=32, max_wait_ms=2.0,
                                    cache_capacity=1024, num_shards=S,
                                    num_replicas=num_replicas,
                                    telemetry=telemetry),
            index=base.index)
        run_query_stream(svc, stream[:500], chunk=64)          # warm
        lat = run_query_stream(svc, stream, chunk=64)
        qps[telemetry] = len(stream) / float(lat.sum())
    overhead = 1.0 - qps[True] / qps[False]
    rep.add(stage="telemetry_overhead", shards=S,
            qps_off=round(qps[False], 1), qps_on=round(qps[True], 1),
            overhead_frac=round(overhead, 4))
    results["telemetry_overhead"] = dict(
        shards=S, qps_off=qps[False], qps_on=qps[True],
        overhead_frac=overhead)

    # -- tracing-enabled run: spans -> latency decomposition + exports -- #
    sample_rate = 1.0 if smoke else 0.05
    svc = ShardedRLCService.build(
        g, ShardedServiceConfig(k=k, batch_size=32, max_wait_ms=2.0,
                                cache_capacity=1024, num_shards=S,
                                num_replicas=num_replicas,
                                trace_sample_rate=sample_rate),
        index=base.index)
    lat = run_query_stream(svc, stream, chunk=64)
    reg = svc.obs.registry
    decomposition = dict(
        q_p50_us=round(float(np.percentile(lat, 50)) * 1e6, 1),
        q_p99_us=round(float(np.percentile(lat, 99)) * 1e6, 1),
        queue_wait=hist_summary_us(reg, "rlc_batcher_queue_wait_seconds"),
        route_local=hist_summary_us(reg, "rlc_fanout_subbatch_seconds",
                                    dict(path="local")),
        route_remote=hist_summary_us(reg, "rlc_fanout_subbatch_seconds",
                                     dict(path="remote")),
        executor=hist_summary_us(reg, "rlc_executor_batch_seconds"))
    results["latency_decomposition"] = decomposition
    results["telemetry"] = svc.telemetry_snapshot(
        extra=dict(latency_decomposition=decomposition))
    results["tracing"] = svc.obs.tracer.stats()   # includes sample_rate
    trace = svc.chrome_trace()
    rep.add(stage="tracing", shards=S, sample_rate=sample_rate,
            spans=len(trace["traceEvents"]) - 1,
            queue_p99_us=decomposition["queue_wait"]["p99_us"],
            exec_p99_us=decomposition["executor"]["p99_us"])

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "sharded_trace.json"), "w") as f:
        json.dump(trace, f)
    with open(os.path.join(ART, "sharded.prom"), "w") as f:
        f.write(svc.prometheus())
    with open(os.path.join(ART, "sharded.json"), "w") as f:
        json.dump(dict(graph=g.summary(), k=k, requests=n_requests,
                       zipf_exponent=1.0, replicas=num_replicas,
                       shard_counts=list(shard_counts), results=results),
                  f, indent=2, default=str)
    return rep
