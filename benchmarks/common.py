"""Shared benchmark helpers: timing, CSV rows, graph stand-ins.

Real SNAP/KONECT datasets are not available offline; each paper graph gets
a synthetic stand-in with matched |L|, degree profile and cyclicity knobs,
scaled down so the single-core container finishes the suite (paper scale
is reproduced by the same code paths; scale factors recorded per row).
"""
from __future__ import annotations

import csv
import io
import time
import zlib
from typing import Callable, Dict, List

import numpy as np

from repro.graphgen import barabasi_albert, erdos_renyi


def zipf_weights(n: int, exponent: float = 1.0) -> np.ndarray:
    """Zipfian popularity over ``n`` items (the serving benches' workload
    shape — one definition so service/sharded numbers stay comparable)."""
    w = np.arange(1, n + 1, dtype=np.float64) ** (-exponent)
    return w / w.sum()


def run_query_stream(svc, stream, chunk: int) -> np.ndarray:
    """Feed a query stream through a service in arrival chunks; returns
    per-query latencies (seconds). ``svc`` is any object with the
    ``query_batch`` serving surface (RLCService or ShardedRLCService)."""
    lat = []
    for i in range(0, len(stream), chunk):
        batch = stream[i:i + chunk]
        t0 = time.perf_counter()
        svc.query_batch(batch)
        dt = time.perf_counter() - t0
        lat.extend([dt / len(batch)] * len(batch))
    return np.asarray(lat)


def hist_summary_us(registry, name: str, labels: Dict[str, str] = None
                    ) -> Dict[str, float]:
    """Pool every series of one registry histogram (optionally filtered by
    a label subset) into ``{count, p50_us, p99_us}``.

    Percentiles come from the pooled reservoir samples — exact while each
    series is below its cap; the serving benches use this to decompose
    request latency into queue-wait / route / executor components."""
    m = registry.get(name)
    samples: List[float] = []
    count = 0
    if m is not None:
        for key, cell in m.series():
            lab = dict(zip(m.labelnames, key))
            if labels and any(lab.get(k) != v for k, v in labels.items()):
                continue
            samples.extend(cell.reservoir.samples)
            count += cell.reservoir.count
    if not samples:
        return dict(count=0, p50_us=0.0, p99_us=0.0)
    arr = np.asarray(samples)
    return dict(count=int(count),
                p50_us=round(float(np.percentile(arr, 50)) * 1e6, 1),
                p99_us=round(float(np.percentile(arr, 99)) * 1e6, 1))


def warm_service(svc, stream, chunk: int = 64,
                 backend: str = None) -> Dict[str, float]:
    """Precompile and warm a service outside the measured window, so the
    measured run shows steady-state serving.

    Two passes: (1) every executor (single-host ``svc.executor``, or each
    replica of a sharded service) runs one batch per power-of-two shape
    up to the largest batch the SLO controller may grow to — since the
    scheduler stopped padding, the jit backends pad to pow2 internally,
    so this is the complete shape set and its elapsed time is the XLA
    compile cost (returned as ``compile_s``; it used to surface as a
    ~350ms ``exec_p99_us`` outlier in the measured window); (2) one
    unmeasured pass of ``stream`` through the serving path.

    Resets afterwards: per-backend/per-path latency recorders, the
    latency histograms' reservoirs, the result cache (contents + stats),
    and the served-query counter. Monotonic counters (registry totals,
    shed/admission counts) are left alone — exporters must stay
    cumulative.
    """
    from repro.obs import Reservoir
    from repro.service.cache import CacheStats
    from repro.service.executor import BACKENDS
    from repro.service.metrics import LatencyRecorder

    backend = backend or svc.config.backend
    slo = svc.ctl.slo
    max_b = max(svc.batcher.batch_size,
                slo.max_batch if slo is not None else 0)
    executors = []
    ex = getattr(svc, "executor", None)
    if ex is not None:
        executors.append(ex)
    for rs in getattr(svc, "shards", ()):
        executors.extend(rep.executor for rep in rs.replicas)

    t0 = time.perf_counter()
    n = 1
    while n <= max_b:
        z = np.zeros(n, np.int32)
        for ex in executors:
            ex.execute(z, z, z, backend=backend)
        n *= 2
    compile_s = time.perf_counter() - t0
    run_query_stream(svc, stream, chunk=chunk)
    warm_s = time.perf_counter() - t0

    def fresh_recorders(obj, names):
        obj.recorders = {n: LatencyRecorder(n) for n in names}

    for ex in executors:
        fresh_recorders(ex, BACKENDS)
    fanout = getattr(svc, "fanout", None)
    if fanout is not None:
        fresh_recorders(fanout, ("local", "remote"))
    reg = svc.obs.registry
    for name in ("rlc_executor_batch_seconds",
                 "rlc_batcher_queue_wait_seconds",
                 "rlc_fanout_subbatch_seconds"):
        m = reg.get(name)
        if m is not None:
            for _key, cell in m.series():
                cell.reservoir = Reservoir(cell.reservoir.cap)
    svc.cache.clear()
    svc.cache.stats = CacheStats()
    svc.queries_served = 0
    return dict(warm_s=round(warm_s, 3), compile_s=round(compile_s, 4))


def timeit(fn: Callable, repeats: int = 1) -> float:
    """Median wall seconds over ``repeats`` calls."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


class Report:
    def __init__(self, name: str):
        self.name = name
        self.rows: List[Dict] = []

    def add(self, **kw):
        self.rows.append(kw)
        print(f"[{self.name}] " + " ".join(f"{k}={v}" for k, v in kw.items()),
              flush=True)

    def to_csv(self) -> str:
        if not self.rows:
            return ""
        keys: List[str] = []
        for r in self.rows:              # union, first-seen order
            for k in r:
                if k not in keys:
                    keys.append(k)
        buf = io.StringIO()
        w = csv.DictWriter(buf, fieldnames=keys, restval="")
        w.writeheader()
        for r in self.rows:
            w.writerow(r)
        return buf.getvalue()


# Scaled-down stand-ins for the paper's Table III graphs (quick mode).
# (name, |V|, avg_degree, |L|, family)
PAPER_GRAPH_STANDINS = [
    ("AD", 400, 8.0, 3, "ba"),      # Advogato: dense, few labels, loops
    ("EP", 600, 6.8, 8, "ba"),      # Soc-Epinions
    ("TW", 800, 1.8, 8, "er"),      # Twitter-ICWSM: sparse
    ("WN", 700, 4.3, 8, "er"),      # Web-NotreDame
    ("WG", 800, 5.7, 8, "ba"),      # Web-Google
]


def standin_graph(name: str, scale: float = 1.0):
    # crc32, NOT hash(): str hashing is randomized per process, which made
    # the "same" stand-in a different graph on every run — bench rows and
    # cross-backend comparisons were not reproducible across processes.
    for nm, v, d, l, fam in PAPER_GRAPH_STANDINS:
        if nm == name:
            n = int(v * scale)
            seed = zlib.crc32(nm.encode()) % 2**31
            if fam == "ba":
                return barabasi_albert(n, max(2, int(d / 2)), l, seed=seed)
            return erdos_renyi(n, d, l, seed=seed)
    raise KeyError(name)
