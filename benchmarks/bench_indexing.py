"""Paper Table IV: indexing time (IT) and index size (IS), RLC vs ETC.

Reproduces the paper's claim set on scaled-down stand-ins of its graphs:
the RLC index builds orders of magnitude faster and smaller than the
extended transitive closure; pruning rules drive both gaps.
"""
from __future__ import annotations

import time

from repro.core.baselines import ETC
from repro.core.index_builder import build_rlc_index_with_stats

from .common import PAPER_GRAPH_STANDINS, Report, standin_graph, timeit


def run(quick: bool = True, k: int = 2) -> Report:
    rep = Report("indexing.tableIV")
    names = [n for n, *_ in PAPER_GRAPH_STANDINS]
    if quick:
        names = names[:3]
    for name in names:
        g = standin_graph(name)
        t0 = time.perf_counter()
        idx, stats = build_rlc_index_with_stats(g, k)
        rlc_it = time.perf_counter() - t0
        t0 = time.perf_counter()
        etc = ETC(g, k)
        etc_it = time.perf_counter() - t0
        rep.add(graph=name, V=g.num_vertices, E=g.num_edges,
                L=g.num_labels, loops=g.loop_count(),
                rlc_it_s=round(rlc_it, 3),
                rlc_is_bytes=idx.size_bytes(),
                rlc_entries=idx.num_entries(),
                etc_it_s=round(etc_it, 3),
                etc_is_bytes=etc.size_bytes(),
                etc_entries=etc.num_entries(),
                it_speedup=round(etc_it / max(rlc_it, 1e-9), 1),
                is_ratio=round(etc.size_bytes()
                               / max(idx.size_bytes(), 1), 1),
                pr1=stats.pruned_pr1, pr2=stats.pruned_pr2,
                pr3=stats.pr3_cuts)
    return rep


def run_pruning_ablation(k: int = 2) -> Report:
    """Paper's pruning-impact observation: build with/without PR rules."""
    rep = Report("indexing.pruning")
    g = standin_graph("AD")
    for flags, label in [
            (dict(), "pr123"),
            (dict(use_pr1=False), "no-pr1"),
            (dict(use_pr3=False), "no-pr3"),
            (dict(use_pr1=False, use_pr2=False, use_pr3=False), "none")]:
        t0 = time.perf_counter()
        idx, stats = build_rlc_index_with_stats(g, k, **flags)
        rep.add(variant=label, it_s=round(time.perf_counter() - t0, 3),
                entries=idx.num_entries(),
                searched=stats.kernel_search_states
                + stats.kernel_bfs_states)
    return rep
