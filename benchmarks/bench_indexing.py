"""Paper Table IV: indexing time (IT) and index size (IS), RLC vs ETC —
plus the build-backend axis added with the staged build pipeline.

Reproduces the paper's claim set on scaled-down stand-ins of its graphs:
the RLC index builds orders of magnitude faster and smaller than the
extended transitive closure; pruning rules drive both gaps. The backend
axis then measures the same build through each :mod:`repro.build`
backend (python reference vs batched numpy vs pallas), asserting entry
equality and reporting per-graph + aggregate speedups, and scales the
parallel epoch/merge backend across 1/2/4 workers. Results land in the
orchestrator CSV and ``benchmarks/artifacts/indexing.json``.

The pallas backend only *interprets* its kernels on CPU (hours, not
seconds) — the backend axis includes it only when a real accelerator
backs jax, and validates it on a tiny stand-in otherwise.
"""
from __future__ import annotations

import gc
import json
import os
import time

from repro.build import build_rlc_index_with_stats, get_backend
from repro.build.parallel import ParallelBackend
from repro.core.baselines import ETC

from .common import PAPER_GRAPH_STANDINS, Report, standin_graph

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def _quick_names(quick: bool):
    names = [n for n, *_ in PAPER_GRAPH_STANDINS]
    return names[:3] if quick else names


def run(quick: bool = True, smoke: bool = False, k: int = 2) -> Report:
    rep = Report("indexing.tableIV")
    names = ["AD", "TW"] if smoke else _quick_names(quick)
    scale = 0.3 if smoke else 1.0
    for name in names:
        g = standin_graph(name, scale=scale)
        t0 = time.perf_counter()
        idx, stats = build_rlc_index_with_stats(g, k, backend="python")
        rlc_it = time.perf_counter() - t0
        t0 = time.perf_counter()
        etc = ETC(g, k)
        etc_it = time.perf_counter() - t0
        rep.add(graph=name, V=g.num_vertices, E=g.num_edges,
                L=g.num_labels, loops=g.loop_count(),
                rlc_it_s=round(rlc_it, 3),
                rlc_is_bytes=idx.size_bytes(),
                rlc_entries=idx.num_entries(),
                etc_it_s=round(etc_it, 3),
                etc_is_bytes=etc.size_bytes(),
                etc_entries=etc.num_entries(),
                it_speedup=round(etc_it / max(rlc_it, 1e-9), 1),
                is_ratio=round(etc.size_bytes()
                               / max(idx.size_bytes(), 1), 1),
                pr1=stats.pruned_pr1, pr2=stats.pruned_pr2,
                pr3=stats.pr3_cuts)
    return rep


def run_pruning_ablation(smoke: bool = False, k: int = 2) -> Report:
    """Paper's pruning-impact observation: build with/without PR rules."""
    rep = Report("indexing.pruning")
    g = standin_graph("AD", scale=0.3 if smoke else 1.0)
    for flags, label in [
            (dict(), "pr123"),
            (dict(use_pr1=False), "no-pr1"),
            (dict(use_pr3=False), "no-pr3"),
            (dict(use_pr1=False, use_pr2=False, use_pr3=False), "none")]:
        t0 = time.perf_counter()
        idx, stats = build_rlc_index_with_stats(g, k, backend="python",
                                                **flags)
        rep.add(variant=label, it_s=round(time.perf_counter() - t0, 3),
                entries=idx.num_entries(),
                searched=stats.kernel_search_states
                + stats.kernel_bfs_states)
    return rep


# --------------------------------------------------------------------- #
# Worker-scaling axis (parallel epoch/merge backend)
# --------------------------------------------------------------------- #
WORKER_AXIS = (1, 2, 4)


def _parallel_scaling(rep, summary, graphs, refs, numpy_s, k,
                      repeats) -> None:
    """Parallel-backend build at 1/2/4 workers on the same stand-ins.

    w=1 takes the sequential fast path (measured wall time); w>1 uses
    the coordinator's virtual-time ``makespan_s`` — the executor runs
    workers inline and sequences completions on a virtual timeline, so
    the number is the modeled parallel wall time and stays meaningful
    on boxes with fewer cores than workers (this one may have 1). Each
    measurement is best-of-``repeats`` and asserted entry- and
    counter-identical to the python reference. The headline
    ``parallel_speedup`` is aggregate numpy wall over aggregate
    max-worker makespan.
    """
    ptotals = {w: 0.0 for w in WORKER_AXIS}
    par_rows = []
    for name, g in graphs.items():
        prow = dict(graph=name)
        binfo = {}
        for w in WORKER_AXIS:
            best, built = None, None
            for _ in range(repeats):
                be = ParallelBackend(workers=w, executor="inline")
                gc.collect()   # same hygiene as the backend loop
                t0 = time.perf_counter()
                idx, stats = be.build(g, k)
                wall = time.perf_counter() - t0
                info = be.last_build_info
                dt = (info["makespan_s"]
                      if info.get("mode") == "parallel" else wall)
                if best is None or dt < best:
                    best = dt
                built = (idx.num_entries(), stats.counters())
                if w == WORKER_AXIS[-1]:
                    binfo = info
            if built != refs[name]:
                raise AssertionError(
                    f"parallel(w={w}) diverged from python on {name}: "
                    f"{built} != {refs[name]}")
            ptotals[w] += best
            prow[f"w{w}_s"] = round(best, 4)
        wmax = WORKER_AXIS[-1]
        prow["speedup_vs_numpy"] = round(
            numpy_s[name] / max(prow[f"w{wmax}_s"], 1e-9), 2)
        prow["epochs"] = binfo.get("epochs", 0)
        prow["stale_reruns"] = binfo.get("stale_reruns", 0)
        prow["thinned"] = bool(binfo.get("thinned", False))
        rep.add(**prow)
        prow["dag"] = binfo.get("dag", {})   # width/depth/serial_frac
        par_rows.append(prow)
    wmax = WORKER_AXIS[-1]
    summary["parallel"] = dict(
        workers=list(WORKER_AXIS), executor="inline",
        model="virtual-makespan", cpu_count=os.cpu_count(),
        aggregate_s={str(w): round(ptotals[w], 4) for w in WORKER_AXIS},
        rows=par_rows)
    summary["parallel_speedup"] = round(
        summary["aggregate_s"]["numpy"] / max(ptotals[wmax], 1e-9), 2)
    rep.add(graph="AGGREGATE",
            **{f"w{w}_s": round(ptotals[w], 4) for w in WORKER_AXIS},
            parallel_speedup=summary["parallel_speedup"])


# --------------------------------------------------------------------- #
# Build-backend axis (staged pipeline: python vs numpy vs pallas)
# --------------------------------------------------------------------- #
def _pallas_on_device() -> bool:
    try:
        import jax
        return jax.default_backend() != "cpu"
    except Exception:
        return False


def run_backends(quick: bool = True, smoke: bool = False, k: int = 2,
                 scale: float = 1.0, repeats: int = 2) -> Report:
    """Per-backend build times on the stand-ins + equality check.

    Emits ``artifacts/indexing.json`` with per-graph rows, per-backend
    aggregate wall time, the numpy-vs-python aggregate speedup, and the
    worker-scaling axis of the parallel backend (``parallel_speedup``
    headline + per-graph DAG shape stats — see
    :func:`_parallel_scaling`).
    """
    rep = Report("indexing.backends")
    if smoke:
        scale = min(scale, 0.3)
        repeats = 1
    backends = ["python", "numpy"]
    if _pallas_on_device():
        backends.append("pallas")
    totals = {b: 0.0 for b in backends}
    json_rows = []
    graphs, refs, numpy_s = {}, {}, {}
    for name in _quick_names(quick):
        g = graphs[name] = standin_graph(name, scale=scale)
        row = dict(graph=name, V=g.num_vertices, E=g.num_edges,
                   L=g.num_labels)
        entries = {}
        for b in backends:
            best = None
            for _ in range(max(1, repeats)):
                backend = get_backend(b)
                gc.collect()   # a pause inside a ~0.1 s build sample
                # is pure noise; collect between, not during
                t0 = time.perf_counter()
                idx, stats = backend.build(g, k)
                dt = time.perf_counter() - t0
                best = dt if best is None else min(best, dt)
            totals[b] += best
            entries[b] = (idx.num_entries(), stats.counters())
            row[f"{b}_s"] = round(best, 4)
        ref = refs[name] = entries["python"]
        numpy_s[name] = row["numpy_s"]
        for b in backends[1:]:
            if entries[b] != ref:
                raise AssertionError(
                    f"backend {b} diverged from python on {name}: "
                    f"{entries[b]} != {ref}")
            row[f"{b}_speedup"] = round(row["python_s"]
                                        / max(row[f"{b}_s"], 1e-9), 2)
        rep.add(**row)
        json_rows.append(row)
    agg = {b: round(totals[b], 4) for b in backends}
    summary = dict(graphs=_quick_names(quick), k=k, scale=scale,
                   aggregate_s=agg,
                   numpy_aggregate_speedup=round(
                       agg["python"] / max(agg["numpy"], 1e-9), 2),
                   pallas_included=("pallas" in backends),
                   rows=json_rows)
    # parallel builds are sub-second, so extra repeats are cheap — and
    # best-of-N is the only defense against scheduler noise on the
    # shared CI/container boxes these numbers come from
    _parallel_scaling(rep, summary, graphs, refs, numpy_s, k,
                      max(1, repeats) + 3)
    # CPU: validate the pallas backend end-to-end on a tiny stand-in so
    # the artifact always records a kernel-path build.
    if "pallas" not in backends:
        g = standin_graph("TW", scale=0.05)
        t0 = time.perf_counter()
        pidx, pstats = get_backend("pallas", mode="vector").build(g, k)
        ridx, rstats = get_backend("python").build(g, k)
        assert (pidx.num_entries(), pstats.counters()) == \
               (ridx.num_entries(), rstats.counters())
        summary["pallas_smoke"] = dict(
            V=g.num_vertices, E=g.num_edges, mode="interpret",
            s=round(time.perf_counter() - t0, 3),
            entries=pidx.num_entries())
        rep.add(graph="TW@0.05(pallas)", V=g.num_vertices, E=g.num_edges,
                L=g.num_labels, pallas_s=summary["pallas_smoke"]["s"])
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "indexing.json"), "w") as f:
        json.dump(summary, f, indent=2)
    rep.add(graph="AGGREGATE", **{f"{b}_s": agg[b] for b in backends},
            numpy_speedup=summary["numpy_aggregate_speedup"])
    return rep
