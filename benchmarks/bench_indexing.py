"""Paper Table IV: indexing time (IT) and index size (IS), RLC vs ETC —
plus the build-backend axis added with the staged build pipeline.

Reproduces the paper's claim set on scaled-down stand-ins of its graphs:
the RLC index builds orders of magnitude faster and smaller than the
extended transitive closure; pruning rules drive both gaps. The backend
axis then measures the same build through each :mod:`repro.build`
backend (python reference vs batched numpy vs pallas), asserting entry
equality and reporting per-graph + aggregate speedups. Results land in
the orchestrator CSV and ``benchmarks/artifacts/indexing.json``.

The pallas backend only *interprets* its kernels on CPU (hours, not
seconds) — the backend axis includes it only when a real accelerator
backs jax, and validates it on a tiny stand-in otherwise.
"""
from __future__ import annotations

import json
import os
import time

from repro.build import build_rlc_index_with_stats, get_backend
from repro.core.baselines import ETC

from .common import PAPER_GRAPH_STANDINS, Report, standin_graph

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def _quick_names(quick: bool):
    names = [n for n, *_ in PAPER_GRAPH_STANDINS]
    return names[:3] if quick else names


def run(quick: bool = True, smoke: bool = False, k: int = 2) -> Report:
    rep = Report("indexing.tableIV")
    names = ["AD", "TW"] if smoke else _quick_names(quick)
    scale = 0.3 if smoke else 1.0
    for name in names:
        g = standin_graph(name, scale=scale)
        t0 = time.perf_counter()
        idx, stats = build_rlc_index_with_stats(g, k, backend="python")
        rlc_it = time.perf_counter() - t0
        t0 = time.perf_counter()
        etc = ETC(g, k)
        etc_it = time.perf_counter() - t0
        rep.add(graph=name, V=g.num_vertices, E=g.num_edges,
                L=g.num_labels, loops=g.loop_count(),
                rlc_it_s=round(rlc_it, 3),
                rlc_is_bytes=idx.size_bytes(),
                rlc_entries=idx.num_entries(),
                etc_it_s=round(etc_it, 3),
                etc_is_bytes=etc.size_bytes(),
                etc_entries=etc.num_entries(),
                it_speedup=round(etc_it / max(rlc_it, 1e-9), 1),
                is_ratio=round(etc.size_bytes()
                               / max(idx.size_bytes(), 1), 1),
                pr1=stats.pruned_pr1, pr2=stats.pruned_pr2,
                pr3=stats.pr3_cuts)
    return rep


def run_pruning_ablation(smoke: bool = False, k: int = 2) -> Report:
    """Paper's pruning-impact observation: build with/without PR rules."""
    rep = Report("indexing.pruning")
    g = standin_graph("AD", scale=0.3 if smoke else 1.0)
    for flags, label in [
            (dict(), "pr123"),
            (dict(use_pr1=False), "no-pr1"),
            (dict(use_pr3=False), "no-pr3"),
            (dict(use_pr1=False, use_pr2=False, use_pr3=False), "none")]:
        t0 = time.perf_counter()
        idx, stats = build_rlc_index_with_stats(g, k, backend="python",
                                                **flags)
        rep.add(variant=label, it_s=round(time.perf_counter() - t0, 3),
                entries=idx.num_entries(),
                searched=stats.kernel_search_states
                + stats.kernel_bfs_states)
    return rep


# --------------------------------------------------------------------- #
# Build-backend axis (staged pipeline: python vs numpy vs pallas)
# --------------------------------------------------------------------- #
def _pallas_on_device() -> bool:
    try:
        import jax
        return jax.default_backend() != "cpu"
    except Exception:
        return False


def run_backends(quick: bool = True, smoke: bool = False, k: int = 2,
                 scale: float = 1.0, repeats: int = 2) -> Report:
    """Per-backend build times on the stand-ins + equality check.

    Emits ``artifacts/indexing.json`` with per-graph rows, per-backend
    aggregate wall time, and the numpy-vs-python aggregate speedup (the
    acceptance headline).
    """
    rep = Report("indexing.backends")
    if smoke:
        scale = min(scale, 0.3)
        repeats = 1
    backends = ["python", "numpy"]
    if _pallas_on_device():
        backends.append("pallas")
    totals = {b: 0.0 for b in backends}
    json_rows = []
    for name in _quick_names(quick):
        g = standin_graph(name, scale=scale)
        row = dict(graph=name, V=g.num_vertices, E=g.num_edges,
                   L=g.num_labels)
        entries = {}
        for b in backends:
            best = None
            for _ in range(max(1, repeats)):
                backend = get_backend(b)
                t0 = time.perf_counter()
                idx, stats = backend.build(g, k)
                dt = time.perf_counter() - t0
                best = dt if best is None else min(best, dt)
            totals[b] += best
            entries[b] = (idx.num_entries(), stats.counters())
            row[f"{b}_s"] = round(best, 4)
        ref = entries["python"]
        for b in backends[1:]:
            if entries[b] != ref:
                raise AssertionError(
                    f"backend {b} diverged from python on {name}: "
                    f"{entries[b]} != {ref}")
            row[f"{b}_speedup"] = round(row["python_s"]
                                        / max(row[f"{b}_s"], 1e-9), 2)
        rep.add(**row)
        json_rows.append(row)
    agg = {b: round(totals[b], 4) for b in backends}
    summary = dict(graphs=_quick_names(quick), k=k, scale=scale,
                   aggregate_s=agg,
                   numpy_aggregate_speedup=round(
                       agg["python"] / max(agg["numpy"], 1e-9), 2),
                   pallas_included=("pallas" in backends),
                   rows=json_rows)
    # CPU: validate the pallas backend end-to-end on a tiny stand-in so
    # the artifact always records a kernel-path build.
    if "pallas" not in backends:
        g = standin_graph("TW", scale=0.05)
        t0 = time.perf_counter()
        pidx, pstats = get_backend("pallas", mode="vector").build(g, k)
        ridx, rstats = get_backend("python").build(g, k)
        assert (pidx.num_entries(), pstats.counters()) == \
               (ridx.num_entries(), rstats.counters())
        summary["pallas_smoke"] = dict(
            V=g.num_vertices, E=g.num_edges, mode="interpret",
            s=round(time.perf_counter() - t0, 3),
            entries=pidx.num_entries())
        rep.add(graph="TW@0.05(pallas)", V=g.num_vertices, E=g.num_edges,
                L=g.num_labels, pallas_s=summary["pallas_smoke"]["s"])
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "indexing.json"), "w") as f:
        json.dump(summary, f, indent=2)
    rep.add(graph="AGGREGATE", **{f"{b}_s": agg[b] for b in backends},
            numpy_speedup=summary["numpy_aggregate_speedup"])
    return rep
