"""Paper Fig. 4 / Fig. 7: impact of recursive k on indexing time, index
size, and query time (expected: exponential IT/IS growth in k; query time
grows with index size)."""
from __future__ import annotations

import time

from repro.core.index_builder import build_rlc_index
from repro.core.queries import generate_queries

from .common import Report, standin_graph, timeit


def run(quick: bool = True, smoke: bool = False) -> Report:
    rep = Report("k_sweep.fig4")
    names = ["TW"] if quick else ["TW", "WG"]
    ks = (2, 3) if quick else (2, 3, 4)
    n_q = 100 if quick else 1000
    scale = 1.0
    if smoke:
        ks, n_q, scale = (2,), 40, 0.4
    for name in names:
        g = standin_graph(name, scale=scale)
        for k in ks:
            t0 = time.perf_counter()
            idx = build_rlc_index(g, k)
            it = time.perf_counter() - t0
            qs = generate_queries(g, k, n_true=n_q, n_false=n_q, seed=2)
            tq = timeit(lambda: [idx.query(s, t, L)
                                 for s, t, L, _ in qs.all()])
            rep.add(graph=name, k=k, it_s=round(it, 3),
                    is_bytes=idx.size_bytes(),
                    entries=idx.num_entries(),
                    query_ms=round(tq * 1e3, 2),
                    n_queries=len(qs.all()))
    return rep
