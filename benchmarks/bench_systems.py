"""Paper Table V (proxy): speed-ups (SU) and workload-size break-even
points (BEP) of the RLC index over an online graph engine, for
  Q1 = a+          Q2 = (a.b)+          Q3 = (a.b.c)+
  Q4 = a+ . b+     (extended query: index + online traversal)

Offline stand-in for the engines: the NFA-guided BFS evaluator (the same
evaluation strategy Sys1/Sys2/Virtuoso fall back to for RLC queries).
One index with k=3 serves all four queries (paper §VI-C methodology).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.baselines import NFA, bfs_nfa, rlc_index_plus_traversal
from repro.core.index_builder import build_rlc_index

from .common import Report, standin_graph, timeit


def run(quick: bool = True, smoke: bool = False) -> Report:
    rep = Report("systems.tableV")
    # paper's representative graph (k=3 builds get expensive fast: smoke
    # shrinks the stand-in, not the query set shape)
    g = standin_graph("WN", scale=0.25 if smoke else 1.0)
    k = 3
    t0 = time.perf_counter()
    idx = build_rlc_index(g, k)
    build_s = time.perf_counter() - t0
    rep.add(graph="WN-standin", V=g.num_vertices, E=g.num_edges,
            index_build_s=round(build_s, 3),
            index_bytes=idx.size_bytes())

    labels = np.unique(g.edges[:, 1])[:3].tolist()
    a, b, c = (labels + [0, 0])[:3]
    n_pairs = 15 if smoke else (50 if quick else 200)
    rng = np.random.default_rng(4)
    pairs = [(int(rng.integers(g.num_vertices)),
              int(rng.integers(g.num_vertices))) for _ in range(n_pairs)]

    queries = {
        "Q1": ((a,), [(a,)]),
        "Q2": ((a, b), [(a, b)]),
        "Q3": ((a, b, c), [(a, b, c)]),
    }
    for qname, (L, blocks) in queries.items():
        nfa = NFA.from_plus_blocks(blocks)
        t_engine = timeit(lambda: [bfs_nfa(g, s, t, nfa)
                                   for s, t in pairs])
        t_idx = timeit(lambda: [idx.query(s, t, L) for s, t in pairs])
        # answers must agree
        for s, t in pairs:
            assert idx.query(s, t, L) == bfs_nfa(g, s, t, nfa), (qname, s, t)
        su = t_engine / max(t_idx, 1e-9)
        per_q_gain = (t_engine - t_idx) / n_pairs
        bep = int(np.ceil(build_s / per_q_gain)) if per_q_gain > 0 else -1
        rep.add(query=qname, n=n_pairs,
                engine_ms=round(t_engine * 1e3, 2),
                rlc_ms=round(t_idx * 1e3, 2),
                speedup=round(su, 1), bep=bep)

    # Q4 extended: a+ ∘ b+ via index + online traversal (paper §VI-C)
    nfa4 = NFA.from_plus_blocks([(a,), (b,)])
    t_engine = timeit(lambda: [bfs_nfa(g, s, t, nfa4) for s, t in pairs])
    t_q4 = timeit(lambda: [rlc_index_plus_traversal(idx, g, s, t,
                                                    [(a,), (b,)])
                           for s, t in pairs])
    for s, t in pairs:
        assert rlc_index_plus_traversal(idx, g, s, t, [(a,), (b,)]) == \
            bfs_nfa(g, s, t, nfa4), (s, t)
    per_q_gain = (t_engine - t_q4) / n_pairs
    rep.add(query="Q4", n=n_pairs, engine_ms=round(t_engine * 1e3, 2),
            rlc_ms=round(t_q4 * 1e3, 2),
            speedup=round(t_engine / max(t_q4, 1e-9), 1),
            bep=int(np.ceil(build_s / per_q_gain))
            if per_q_gain > 0 else -1)
    return rep
