"""Device-engine benchmarks (beyond-paper): dense semiring engine,
hub-batched device build, batched query joins (jnp vs Pallas-interpret),
bitpacked vs f32 semiring matmul memory footprint."""
from __future__ import annotations

import time

import numpy as np

from repro.core.dense import DenseEngine, build_condensed_device
from repro.core.device_index import DeviceIndex
from repro.core.index_builder import build_rlc_index
from repro.core.queries import generate_queries
from repro.graphgen import erdos_renyi

from .common import Report, timeit


def run(quick: bool = True, smoke: bool = False, k: int = 2) -> Report:
    rep = Report("device_engine")
    n = 96 if smoke else (256 if quick else 1024)
    g = erdos_renyi(n, 4, 8, seed=21)

    t0 = time.perf_counter()
    eng = DenseEngine.build(g, k)
    t_dense = time.perf_counter() - t0
    rep.add(stage="dense_engine_Sk", V=n, E=g.num_edges,
            mrs=len(eng.mrs), seconds=round(t_dense, 3),
            true_pairs=eng.num_true_pairs())

    for hb in (1, 16, 64):
        t0 = time.perf_counter()
        idx, _ = build_condensed_device(g, k, hub_batch=hb, reach=eng.reach)
        rep.add(stage="device_build", hub_batch=hb,
                seconds=round(time.perf_counter() - t0, 3),
                entries=idx.num_entries())

    # batched query join: jnp vs pallas(interpret)
    ref_idx = build_rlc_index(g, k)
    dev = DeviceIndex.from_index(ref_idx, g.num_labels)
    qs = generate_queries(g, k, n_true=200, n_false=200, seed=9)
    sa = np.array([q[0] for q in qs.all()], np.int32)
    ta = np.array([q[1] for q in qs.all()], np.int32)
    ma = np.array([dev.mr_ids[q[2]] for q in qs.all()], np.int32)
    dev.query_batch(sa, ta, ma)
    t_jnp = timeit(lambda: dev.query_batch(sa, ta, ma))
    dev.query_batch(sa, ta, ma, use_pallas=True)
    t_pl = timeit(lambda: dev.query_batch(sa, ta, ma, use_pallas=True))
    rep.add(stage="batched_query", n=len(sa), row_len=dev.row_len,
            jnp_ms=round(t_jnp * 1e3, 2),
            pallas_interp_ms=round(t_pl * 1e3, 2),
            note="pallas timed in CPU interpreter; TPU perf from roofline")
    return rep
